//! High-level cost evaluator over the PJRT artifacts: pads a live
//! `(Graph, Partition)` problem up to the nearest compiled shape,
//! executes `refine_step`, and unpacks the (unpadded) outputs.
//!
//! Padding contract (mirrors `python/compile/kernels/ref.py`):
//! * padded nodes: `b = 0`, no edges, assigned to machine 0 — their cost
//!   rows are inert and their dissatisfaction is exactly 0;
//! * padded machines: `w = 1`, `wmask = 0` — a `BIG` additive penalty
//!   keeps min/argmin away from them.

use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::partition::{MachineConfig, Partition};
use crate::runtime::artifacts::ArtifactManifest;
use crate::runtime::pjrt::{PjrtContext, RefineStepExecutable};

/// Unpadded outputs of one `refine_step` execution.
#[derive(Debug, Clone)]
pub struct RefineStepOutput {
    pub n: usize,
    pub k: usize,
    /// Row-major N×K framework-A costs.
    pub costs_a: Vec<f32>,
    /// Row-major N×K framework-B costs.
    pub costs_b: Vec<f32>,
    pub dissat_a: Vec<f32>,
    pub dissat_b: Vec<f32>,
    pub best_a: Vec<i32>,
    pub best_b: Vec<i32>,
    pub c0: f32,
    pub c0_tilde: f32,
}

/// Evaluator holding the PJRT context plus lazily compiled executables
/// for each padded shape in the manifest.
pub struct PjrtCostEvaluator {
    ctx: PjrtContext,
    manifest: ArtifactManifest,
    compiled: Vec<Option<RefineStepExecutable>>,
    // Reusable padded input buffers (avoid re-allocating 4 MiB per call).
    buf_adj: Vec<f32>,
    buf_xt: Vec<f32>,
    buf_b: Vec<f32>,
}

impl PjrtCostEvaluator {
    /// Create from the default artifacts directory.
    pub fn from_default_dir() -> Result<PjrtCostEvaluator> {
        Self::from_dir(ArtifactManifest::default_dir())
    }

    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<PjrtCostEvaluator> {
        let manifest = ArtifactManifest::load_dir(dir)?;
        let ctx = PjrtContext::cpu()?;
        let compiled = manifest.specs.iter().map(|_| None).collect();
        Ok(PjrtCostEvaluator {
            ctx,
            manifest,
            compiled,
            buf_adj: Vec::new(),
            buf_xt: Vec::new(),
            buf_b: Vec::new(),
        })
    }

    /// Largest problem size this evaluator supports.
    pub fn max_nodes(&self) -> usize {
        self.manifest.specs.iter().map(|s| s.n).max().unwrap_or(0)
    }

    fn exe_for(&mut self, n: usize, k: usize) -> Result<usize> {
        let idx = self
            .manifest
            .specs
            .iter()
            .position(|s| s.n >= n && s.k >= k)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no artifact fits n={n}, k={k} (max n={}, run `make artifacts`)",
                    self.max_nodes()
                ))
            })?;
        if self.compiled[idx].is_none() {
            let spec = &self.manifest.specs[idx];
            self.compiled[idx] = Some(RefineStepExecutable::load(&self.ctx, spec)?);
        }
        Ok(idx)
    }

    /// Evaluate the full refine step for a live problem.
    pub fn evaluate(
        &mut self,
        graph: &Graph,
        machines: &MachineConfig,
        part: &Partition,
        mu: f64,
    ) -> Result<RefineStepOutput> {
        let n = graph.node_count();
        let k = machines.count();
        let idx = self.exe_for(n, k)?;
        let (pn, pk) = {
            let spec = &self.manifest.specs[idx];
            (spec.n, spec.k)
        };

        // Pad inputs.
        self.buf_b.clear();
        self.buf_b.resize(pn, 0.0);
        for i in 0..n {
            self.buf_b[i] = graph.node_weight(i) as f32;
        }
        let mut w = vec![1.0f32; pk];
        let mut wmask = vec![0.0f32; pk];
        for m in 0..k {
            w[m] = machines.speed(m) as f32;
            wmask[m] = 1.0;
        }
        self.buf_adj.clear();
        self.buf_adj.resize(pn * pn, 0.0);
        for (u, v, c) in graph.edges() {
            self.buf_adj[u * pn + v] = c as f32;
            self.buf_adj[v * pn + u] = c as f32;
        }
        self.buf_xt.clear();
        self.buf_xt.resize(pn * pk, 0.0);
        for i in 0..pn {
            let m = if i < n { part.machine_of(i) } else { 0 };
            self.buf_xt[i * pk + m] = 1.0;
        }

        let exe = self.compiled[idx].as_ref().expect("compiled above");
        let out = exe.run_padded(&self.buf_b, &w, &wmask, &self.buf_adj, &self.buf_xt, mu as f32)?;

        // Unpad outputs. Order per python/compile/model.py.
        let mat = |lit: &xla::Literal| -> Result<Vec<f32>> {
            let full = lit.to_vec::<f32>()?;
            let mut out = Vec::with_capacity(n * k);
            for i in 0..n {
                out.extend_from_slice(&full[i * pk..i * pk + k]);
            }
            Ok(out)
        };
        let vecf = |lit: &xla::Literal| -> Result<Vec<f32>> {
            Ok(lit.to_vec::<f32>()?[..n].to_vec())
        };
        let veci = |lit: &xla::Literal| -> Result<Vec<i32>> {
            Ok(lit.to_vec::<i32>()?[..n].to_vec())
        };
        let scalar = |lit: &xla::Literal| -> Result<f32> {
            Ok(lit.to_vec::<f32>()?[0])
        };

        Ok(RefineStepOutput {
            n,
            k,
            costs_a: mat(&out[0])?,
            costs_b: mat(&out[1])?,
            dissat_a: vecf(&out[2])?,
            dissat_b: vecf(&out[3])?,
            best_a: veci(&out[4])?,
            best_b: veci(&out[5])?,
            c0: scalar(&out[6])?,
            c0_tilde: scalar(&out[7])?,
        })
    }
}

/// Compare a PJRT output against the native Rust dense evaluator.
/// Returns the maximum relative error across the cost matrices and
/// dissatisfaction vectors (used by tests and the `gtip artifacts`
/// verification subcommand).
pub fn max_rel_error_vs_native(
    graph: &Graph,
    machines: &MachineConfig,
    part: &Partition,
    mu: f64,
    out: &RefineStepOutput,
) -> f64 {
    let native = crate::game::cost::dense_cost_matrices(graph, machines, part, mu);
    let rel = |a: f64, b: f64| -> f64 { (a - b).abs() / (1.0 + a.abs().max(b.abs())) };
    let mut worst: f64 = 0.0;
    for i in 0..out.n {
        for m in 0..out.k {
            worst = worst.max(rel(native.costs_a[i * out.k + m], out.costs_a[i * out.k + m] as f64));
            worst = worst.max(rel(native.costs_b[i * out.k + m], out.costs_b[i * out.k + m] as f64));
        }
        worst = worst.max(rel(native.dissat_a[i], out.dissat_a[i] as f64));
        worst = worst.max(rel(native.dissat_b[i], out.dissat_b[i] as f64));
    }
    // Global costs.
    let c0 = crate::partition::global_cost::c0(graph, machines, part, mu);
    let c0t = crate::partition::global_cost::c0_tilde(graph, machines, part, mu);
    worst = worst.max(rel(c0, out.c0 as f64));
    worst = worst.max(rel(c0t, out.c0_tilde as f64));
    worst
}

//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python runs once at build time (`make artifacts`); afterwards the
//! `gtip` binary is self-contained: [`pjrt::RefineStepExecutable`] wraps
//! a compiled PJRT executable per padded shape and
//! [`cost_eval::PjrtCostEvaluator`] pads live problems up to the nearest
//! compiled shape and unpacks the outputs.
//!
//! The executor needs the native `xla` crate, which cannot be fetched in
//! offline builds, so the PJRT half is gated behind the `pjrt` cargo
//! feature. The artifact manifest ([`artifacts`]) is plain std and stays
//! available either way so manifests can be inspected and validated
//! without the runtime.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod cost_eval;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
#[cfg(feature = "pjrt")]
pub use cost_eval::{PjrtCostEvaluator, RefineStepOutput};

//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python runs once at build time (`make artifacts`); afterwards the
//! `gtip` binary is self-contained: [`pjrt::RefineStepExecutable`] wraps
//! a compiled PJRT executable per padded shape and
//! [`cost_eval::PjrtCostEvaluator`] pads live problems up to the nearest
//! compiled shape and unpacks the outputs.

pub mod artifacts;
pub mod cost_eval;
pub mod pjrt;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use cost_eval::{PjrtCostEvaluator, RefineStepOutput};

//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO text,
//! compile once, execute many times.
//!
//! Follows /opt/xla-example/load_hlo exactly: HLO *text* interchange
//! (`HloModuleProto::from_text_file` reassigns 64-bit jax ids), lowered
//! with `return_tuple=True`, so execution yields one tuple literal that
//! is unpacked with `to_tuple()`.

use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::artifacts::ArtifactSpec;

/// Shared PJRT CPU client (one per process is plenty).
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    pub fn cpu() -> Result<PjrtContext> {
        Ok(PjrtContext { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn compile_file(&self, path: impl AsRef<Path>) -> Result<CompiledHlo> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            Error::Runtime(format!("loading {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(CompiledHlo { exe })
    }
}

/// One compiled executable.
pub struct CompiledHlo {
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledHlo {
    /// Execute with literal inputs; returns the unpacked output tuple.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let out = result[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }
}

/// The compiled `refine_step` program for one padded shape, with typed
/// input marshalling.
pub struct RefineStepExecutable {
    compiled: CompiledHlo,
    pub spec: ArtifactSpec,
}

impl RefineStepExecutable {
    pub fn load(ctx: &PjrtContext, spec: &ArtifactSpec) -> Result<RefineStepExecutable> {
        Ok(RefineStepExecutable { compiled: ctx.compile_file(&spec.path)?, spec: spec.clone() })
    }

    /// Execute on pre-padded f32 buffers.
    ///
    /// * `b`: len `n` — node weights
    /// * `w`: len `k` — speeds (1.0 for padding machines)
    /// * `wmask`: len `k` — 1 for real machines
    /// * `adj`: len `n*n` row-major
    /// * `xt`: len `n*k` row-major one-hot
    /// * `mu`: scalar
    ///
    /// Output order matches `python/compile/model.py::refine_step`.
    pub fn run_padded(
        &self,
        b: &[f32],
        w: &[f32],
        wmask: &[f32],
        adj: &[f32],
        xt: &[f32],
        mu: f32,
    ) -> Result<Vec<xla::Literal>> {
        let n = self.spec.n as i64;
        let k = self.spec.k as i64;
        if b.len() != self.spec.n
            || w.len() != self.spec.k
            || wmask.len() != self.spec.k
            || adj.len() != self.spec.n * self.spec.n
            || xt.len() != self.spec.n * self.spec.k
        {
            return Err(Error::Runtime(format!(
                "input shape mismatch for artifact {} (n={}, k={})",
                self.spec.name, self.spec.n, self.spec.k
            )));
        }
        let inputs = [
            xla::Literal::vec1(b),
            xla::Literal::vec1(w),
            xla::Literal::vec1(wmask),
            xla::Literal::vec1(adj).reshape(&[n, n])?,
            xla::Literal::vec1(xt).reshape(&[n, k])?,
            xla::Literal::scalar(mu),
        ];
        let out = self.compiled.execute(&inputs)?;
        if out.len() != 8 {
            return Err(Error::Runtime(format!(
                "expected 8 outputs from refine_step, got {}",
                out.len()
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    //! Runtime smoke tests live in `rust/tests/integration_runtime.rs`
    //! (they need the artifacts from `make artifacts`). Here we only test
    //! error paths that need no artifacts.
    use super::*;

    #[test]
    fn missing_file_is_clean_error() {
        let ctx = match PjrtContext::cpu() {
            Ok(c) => c,
            Err(e) => panic!("PJRT CPU client unavailable: {e}"),
        };
        let err = ctx.compile_file("/nonexistent/file.hlo.txt");
        assert!(err.is_err());
    }
}

//! Dynamic-refinement driver: the full §6.1 experiment loop.
//!
//! Runs the optimistic engine tick by tick; every `refine_every` wall
//! ticks it (1) measures the live node/edge weights from LP state
//! (§6.1), (2) installs them into the LP graph, (3) runs the
//! game-theoretic iterative refinement to convergence from the current
//! assignment, and (4) swaps the improved assignment into the running
//! engine. `refine_every = 0` disables refinement (the Fig. 9 baseline).

use crate::game::cost::Framework;
use crate::game::refine::{RefineEngine, RefineOptions};
use crate::graph::Graph;
use crate::partition::initial::grow_partition;
use crate::partition::{MachineConfig, Partition};
use crate::sim::engine::{SimEngine, SimOptions, SimStats};
use crate::sim::weights;
use crate::sim::workload::FloodWorkload;
use crate::util::rng::Pcg32;
use crate::util::stats::Trace;

/// Driver options beyond the engine's.
#[derive(Debug, Clone)]
pub struct DriverOptions {
    pub sim: SimOptions,
    /// Wall ticks between refinements (`partition-refine-freq`);
    /// 0 = never refine.
    pub refine_every: u64,
    /// Cost framework used by refinement.
    pub framework: Framework,
    /// Relative rollback-delay weight μ.
    pub mu: f64,
    /// Optional wall-tick charge per executed node transfer, modeling
    /// migration overhead (the paper ignores it; default 0).
    pub ticks_per_transfer: u64,
}

impl Default for DriverOptions {
    fn default() -> Self {
        DriverOptions {
            sim: SimOptions::default(),
            refine_every: 500,
            framework: Framework::A,
            mu: 8.0,
            ticks_per_transfer: 0,
        }
    }
}

/// Result of a dynamic run.
#[derive(Debug, Clone)]
pub struct DynamicRunReport {
    pub stats: SimStats,
    /// Number of refinement epochs executed.
    pub refinements: usize,
    /// Total node transfers across all epochs.
    pub transfers: usize,
    /// Wall ticks charged for migrations (if `ticks_per_transfer > 0`).
    pub migration_ticks: u64,
    /// Machine-load traces (only populated if `sim.trace_every > 0`).
    pub load_traces: Vec<Trace>,
    /// Potential at the end of each refinement epoch.
    pub epoch_potentials: Vec<f64>,
}

/// Total simulation time including migration charges — the y-axis of
/// Figs. 7/8.
impl DynamicRunReport {
    pub fn total_time(&self) -> u64 {
        self.stats.ticks + self.migration_ticks
    }
}

/// Run a full dynamically-refined simulation.
///
/// `graph` provides the LP topology; its weights are treated as scratch
/// (a private copy is re-measured each epoch). The initial partition is
/// App. A hop-growth from focal nodes (unit weights, §4.1).
pub fn run_dynamic(
    graph: &Graph,
    machines: &MachineConfig,
    workload: FloodWorkload,
    options: &DriverOptions,
    rng: &mut Pcg32,
) -> DynamicRunReport {
    // LP graph with dynamic weights, private to the refinement side.
    let mut lp_graph = graph.clone();

    // §4.1 initial partitioning (unit weights).
    let initial = grow_partition(&lp_graph, machines, rng);
    run_dynamic_from(graph, &mut lp_graph, machines, initial, workload, options)
}

/// Like [`run_dynamic`] but with an explicit starting partition (used by
/// experiments that compare frameworks from identical starts).
pub fn run_dynamic_from(
    graph: &Graph,
    lp_graph: &mut Graph,
    machines: &MachineConfig,
    initial: Partition,
    workload: FloodWorkload,
    options: &DriverOptions,
) -> DynamicRunReport {
    let mut engine =
        SimEngine::new(graph, machines.clone(), initial, options.sim.clone(), workload.injections);

    let mut refinements = 0;
    let mut transfers = 0;
    let mut migration_ticks = 0u64;
    let mut epoch_potentials = Vec::new();

    loop {
        // Bound fast-forward jumps at the next refinement boundary so
        // the refinement schedule is identical to per-tick stepping.
        let boundary = if options.refine_every > 0 {
            (engine.stats().ticks / options.refine_every + 1) * options.refine_every
        } else {
            options.sim.max_ticks
        };
        if !engine.step_bounded(boundary) {
            break;
        }
        let tick = engine.stats().ticks;
        if tick >= options.sim.max_ticks {
            break;
        }
        if options.refine_every > 0 && tick % options.refine_every == 0 {
            // (1) measure live weights, (2) install, (3) refine, (4) swap.
            let measured = weights::measure(&engine);
            weights::install(lp_graph, &measured);
            let mut part = engine.partition().clone();
            part.rebuild_aggregates(lp_graph);
            let mut refine =
                RefineEngine::new(lp_graph, machines, part, options.mu, options.framework);
            let report = refine.run(&RefineOptions::default());
            transfers += report.transfers;
            migration_ticks += options.ticks_per_transfer * report.transfers as u64;
            epoch_potentials.push(report.final_potential);
            engine.set_partition(refine.into_partition());
            refinements += 1;
        }
    }

    let load_traces = engine.load_traces().to_vec();
    let stats = engine.stats().clone();
    DynamicRunReport {
        stats,
        refinements,
        transfers,
        migration_ticks,
        load_traces,
        epoch_potentials,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::preferential_attachment;
    use crate::sim::workload::WorkloadOptions;

    fn small_setup(seed: u64) -> (Graph, MachineConfig, FloodWorkload) {
        let mut rng = Pcg32::new(seed);
        let g = preferential_attachment(120, 2, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let wl = FloodWorkload::generate(
            &g,
            &WorkloadOptions {
                threads: 40,
                horizon_ticks: 800,
                hot_spot_period: 200,
                ..Default::default()
            },
            &mut rng,
        );
        (g, machines, wl)
    }

    #[test]
    fn dynamic_run_completes_and_refines() {
        let (g, machines, wl) = small_setup(1);
        let mut rng = Pcg32::new(2);
        let opts = DriverOptions { refine_every: 200, ..Default::default() };
        let report = run_dynamic(&g, &machines, wl, &opts, &mut rng);
        assert!(!report.stats.truncated, "run truncated: {:?}", report.stats);
        assert!(report.refinements > 0, "no refinement epochs ran");
        assert_eq!(report.epoch_potentials.len(), report.refinements);
    }

    #[test]
    fn no_refinement_mode() {
        let (g, machines, wl) = small_setup(3);
        let mut rng = Pcg32::new(4);
        let opts = DriverOptions { refine_every: 0, ..Default::default() };
        let report = run_dynamic(&g, &machines, wl, &opts, &mut rng);
        assert_eq!(report.refinements, 0);
        assert_eq!(report.transfers, 0);
        assert!(!report.stats.truncated);
    }

    #[test]
    fn refinement_does_not_break_draining() {
        // Frequent refinement must not lose events or deadlock.
        let (g, machines, wl) = small_setup(5);
        let injected = wl.len() as u64;
        let mut rng = Pcg32::new(6);
        let opts = DriverOptions { refine_every: 50, ..Default::default() };
        let report = run_dynamic(&g, &machines, wl, &opts, &mut rng);
        assert!(!report.stats.truncated);
        // Every injected thread is processed at least once (by its source).
        assert!(
            report.stats.events_processed >= injected,
            "processed {} < injected {injected}",
            report.stats.events_processed
        );
    }

    #[test]
    fn migration_charge_accounted() {
        let (g, machines, wl) = small_setup(7);
        let mut rng = Pcg32::new(8);
        let opts =
            DriverOptions { refine_every: 200, ticks_per_transfer: 2, ..Default::default() };
        let report = run_dynamic(&g, &machines, wl, &opts, &mut rng);
        assert_eq!(report.migration_ticks, 2 * report.transfers as u64);
        assert_eq!(report.total_time(), report.stats.ticks + report.migration_ticks);
    }
}

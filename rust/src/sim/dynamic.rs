//! Closed-loop dynamic rebalancing (§6.1) — the paper's *title*
//! scenario, end to end.
//!
//! [`DynamicDriver`] alternates **simulation epochs** with **refinement
//! epochs**: run the optimistic PDES engine for `epoch_ticks` wall
//! ticks, harvest the per-LP measured loads of the window (events
//! processed, rollbacks, per-edge forward traffic — see
//! [`EpochCounters`]), turn them into fresh node/edge weights through a
//! pluggable [`WeightEstimator`], re-run the game-theoretic refinement
//! *warm-started from the current partition* (sequentially or through
//! the distributed machine-actor coordinator, see [`RefineBackend`]),
//! migrate the LPs on the live engine, and record an [`EpochReport`].
//!
//! Differences from the one-shot `sim::driver` loop kept for the Fig.
//! 7–10 harnesses: epoch-boundary (not modulo-tick) scheduling, windowed
//! activity measurement instead of instantaneous queue lengths only,
//! estimator smoothing/hysteresis to damp migration churn (cf. the
//! self-clustering partitioner of arXiv:1610.01295), a selectable
//! distributed backend, and a per-epoch report stream capturing the
//! potential descent of every refinement.

use std::sync::Arc;

use crate::coordinator::net::ClusterLeader;
use crate::coordinator::{run_distributed, DistributedOptions, OverheadStats, WireError};
use crate::game::cost::Framework;
use crate::game::refine::{RefineEngine, RefineOptions};
use crate::graph::Graph;
use crate::partition::initial::grow_partition;
use crate::partition::{global_cost, MachineConfig, Partition};
use crate::sim::engine::{EpochCounters, Injection, SimEngine, SimOptions, SimStats};
use crate::sim::weights::{self, MeasuredWeights};
use crate::util::rng::Pcg32;
use crate::util::stats::Trace;
use crate::util::table::Table;

/// How measured loads become refinement weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorKind {
    /// Use the latest window's measurement as-is.
    Instantaneous,
    /// Exponentially-weighted moving average across windows.
    Ewma,
    /// EWMA plus a relative dead band: the emitted weight only moves
    /// when the smoothed estimate drifts far enough, damping migration
    /// churn between epochs.
    Hysteresis,
}

impl std::str::FromStr for EstimatorKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "instant" | "instantaneous" => Ok(EstimatorKind::Instantaneous),
            "ewma" => Ok(EstimatorKind::Ewma),
            "hyst" | "hysteresis" => Ok(EstimatorKind::Hysteresis),
            other => Err(format!(
                "unknown estimator {other:?} (expected instant|ewma|hysteresis)"
            )),
        }
    }
}

impl std::fmt::Display for EstimatorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EstimatorKind::Instantaneous => "instant",
            EstimatorKind::Ewma => "ewma",
            EstimatorKind::Hysteresis => "hysteresis",
        })
    }
}

/// Stateful weight estimator fed one [`MeasuredWeights`] per epoch.
#[derive(Debug, Clone)]
pub struct WeightEstimator {
    kind: EstimatorKind,
    /// EWMA smoothing factor in `(0, 1]` (1 = no memory).
    alpha: f64,
    /// Relative dead band of the hysteresis variant.
    deadband: f64,
    node_state: Vec<f64>,
    edge_state: Vec<f64>,
    node_out: Vec<f64>,
    edge_out: Vec<f64>,
    primed: bool,
}

impl WeightEstimator {
    pub fn new(kind: EstimatorKind, alpha: f64, deadband: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of (0, 1]");
        assert!(deadband >= 0.0, "negative dead band");
        WeightEstimator {
            kind,
            alpha,
            deadband,
            node_state: Vec::new(),
            edge_state: Vec::new(),
            node_out: Vec::new(),
            edge_out: Vec::new(),
            primed: false,
        }
    }

    /// Pass-through estimator.
    pub fn instantaneous() -> Self {
        WeightEstimator::new(EstimatorKind::Instantaneous, 1.0, 0.0)
    }

    /// EWMA-smoothed estimator.
    pub fn ewma(alpha: f64) -> Self {
        WeightEstimator::new(EstimatorKind::Ewma, alpha, 0.0)
    }

    /// EWMA plus relative dead band.
    pub fn hysteresis(alpha: f64, deadband: f64) -> Self {
        WeightEstimator::new(EstimatorKind::Hysteresis, alpha, deadband)
    }

    /// Default parameters per kind (used by the CLI).
    pub fn of_kind(kind: EstimatorKind) -> Self {
        match kind {
            EstimatorKind::Instantaneous => WeightEstimator::instantaneous(),
            EstimatorKind::Ewma => WeightEstimator::ewma(0.5),
            EstimatorKind::Hysteresis => WeightEstimator::hysteresis(0.5, 0.25),
        }
    }

    pub fn kind(&self) -> EstimatorKind {
        self.kind
    }

    /// Fold one window's raw measurement into the estimate and return
    /// the weights to hand to the refinement engine.
    pub fn estimate(&mut self, raw: &MeasuredWeights) -> MeasuredWeights {
        if self.kind == EstimatorKind::Instantaneous {
            return raw.clone();
        }
        if !self.primed {
            self.node_state = raw.node_weights.clone();
            self.edge_state = raw.edge_weights.iter().map(|&(_, _, c)| c).collect();
            self.node_out = self.node_state.clone();
            self.edge_out = self.edge_state.clone();
            self.primed = true;
        } else {
            assert_eq!(self.node_state.len(), raw.node_weights.len(), "graph changed shape");
            assert_eq!(self.edge_state.len(), raw.edge_weights.len(), "graph changed shape");
            for (s, &x) in self.node_state.iter_mut().zip(&raw.node_weights) {
                *s = self.alpha * x + (1.0 - self.alpha) * *s;
            }
            for (s, &(_, _, c)) in self.edge_state.iter_mut().zip(&raw.edge_weights) {
                *s = self.alpha * c + (1.0 - self.alpha) * *s;
            }
            match self.kind {
                EstimatorKind::Ewma => {
                    self.node_out.copy_from_slice(&self.node_state);
                    self.edge_out.copy_from_slice(&self.edge_state);
                }
                EstimatorKind::Hysteresis => {
                    let band = self.deadband;
                    for (o, &s) in self.node_out.iter_mut().zip(&self.node_state) {
                        if (s - *o).abs() > band * 1.0f64.max(o.abs()) {
                            *o = s;
                        }
                    }
                    for (o, &s) in self.edge_out.iter_mut().zip(&self.edge_state) {
                        if (s - *o).abs() > band * 1.0f64.max(o.abs()) {
                            *o = s;
                        }
                    }
                }
                EstimatorKind::Instantaneous => unreachable!(),
            }
        }
        MeasuredWeights {
            node_weights: self.node_out.clone(),
            edge_weights: raw
                .edge_weights
                .iter()
                .zip(&self.edge_out)
                .map(|(&(u, v, _), &c)| (u, v, c))
                .collect(),
        }
    }
}

/// Which refinement implementation closes the loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefineBackend {
    /// In-process [`RefineEngine`] (fast path).
    Sequential,
    /// One-thread-per-machine actor protocol
    /// ([`run_distributed`]) — produces the identical equilibrium (same
    /// deterministic turn order) while measuring the O(K) sync traffic.
    Distributed,
}

impl std::str::FromStr for RefineBackend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "seq" | "sequential" => Ok(RefineBackend::Sequential),
            "dist" | "distributed" | "coordinator" => Ok(RefineBackend::Distributed),
            other => Err(format!(
                "unknown backend {other:?} (expected sequential|distributed)"
            )),
        }
    }
}

impl std::fmt::Display for RefineBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RefineBackend::Sequential => "sequential",
            RefineBackend::Distributed => "distributed",
        })
    }
}

/// Options of the closed loop.
#[derive(Debug, Clone)]
pub struct DynamicOptions {
    pub sim: SimOptions,
    /// Wall ticks per simulation epoch; 0 freezes the initial partition
    /// (the static baseline).
    pub epoch_ticks: u64,
    pub framework: Framework,
    /// Relative rollback-delay weight μ.
    pub mu: f64,
    pub backend: RefineBackend,
    /// Wall-tick charge per executed LP migration (the paper ignores
    /// migration cost; default 0).
    pub ticks_per_transfer: u64,
    /// Per-move surcharge `c_mig` priced *inside* the refinement game
    /// (augmented dissatisfaction, DESIGN.md §9): a transfer is only
    /// accepted when its raw cost gain exceeds this many cost units.
    /// Use [`DynamicOptions::charge_transfers`] to derive it from
    /// `ticks_per_transfer` so the game prices exactly what the report
    /// bills. 0 reproduces the paper's charge-free game.
    pub migration_charge: f64,
    /// Cap on refinement epochs (0 = unlimited).
    pub max_refinements: usize,
}

impl Default for DynamicOptions {
    fn default() -> Self {
        DynamicOptions {
            sim: SimOptions::default(),
            epoch_ticks: 200,
            framework: Framework::A,
            mu: 8.0,
            backend: RefineBackend::Sequential,
            ticks_per_transfer: 0,
            migration_charge: 0.0,
            max_refinements: 0,
        }
    }
}

impl DynamicOptions {
    /// Bill each transfer `ticks` wall ticks in the report AND price it
    /// at `c_mig = ticks · tick_value` cost units inside the game, so
    /// refinement only moves an LP when its modeled gain beats what the
    /// migration will cost the run. `tick_value` converts wall ticks to
    /// cost units (1.0 when node weights are events-per-window, the
    /// closed loop's default measurement).
    pub fn charge_transfers(mut self, ticks: u64, tick_value: f64) -> Self {
        assert!(tick_value >= 0.0 && tick_value.is_finite(), "tick value must be finite and >= 0");
        self.ticks_per_transfer = ticks;
        self.migration_charge = ticks as f64 * tick_value;
        self
    }
}

/// What one refinement epoch did.
#[derive(Debug, Clone)]
pub struct EpochRefinement {
    /// Potential on the re-measured weights *before* refining (warm
    /// start = current partition).
    pub potential_before: f64,
    /// Potential at the refined equilibrium. Never exceeds
    /// `potential_before` (Thm 4.1 descent).
    pub potential_after: f64,
    /// LP migrations executed.
    pub transfers: usize,
    /// Wall-tick migration charge of this epoch.
    pub migration_ticks: u64,
    /// In-game migration spend of this epoch: `c_mig · transfers`, in
    /// cost units. `potential_after + migration_cost ≤ potential_before`
    /// is the augmented-descent guarantee (DESIGN.md §9).
    pub migration_cost: f64,
    pub imbalance_before: f64,
    pub imbalance_after: f64,
    /// Whether refinement reached a Nash equilibrium (vs the cap).
    pub converged: bool,
    /// Measured coordinator sync traffic of this epoch (exact wire
    /// bytes) — `None` on the sequential backend, which sends nothing.
    pub overhead: Option<OverheadStats>,
}

/// Per-epoch record of the closed loop.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub epoch: usize,
    /// Simulation-tick window (engine clock; migration stalls excluded).
    pub tick_start: u64,
    pub tick_end: u64,
    /// Wall-clock window including migration stalls: `wall_tick_start`
    /// is `tick_start` plus every earlier epoch's migration charge, and
    /// `wall_tick_end` additionally includes *this* epoch's charge —
    /// epoch wall windows tile `[0, DynamicReport::total_time()]`
    /// exactly, so per-epoch weights and throughput bill migration time
    /// the same way the headline metric does.
    pub wall_tick_start: u64,
    pub wall_tick_end: u64,
    /// Wall-tick migration charge of this epoch's refinement (0 when
    /// the epoch did not refine).
    pub migration_ticks: u64,
    /// Events completed during the window.
    pub events_processed: u64,
    /// Rollback episodes during the window.
    pub rollbacks: u64,
    /// Cross-machine forwards during the window.
    pub cross_machine_forwards: u64,
    /// Events per *wall* tick over the window, migration stall
    /// included — the throughput the rebalancer tries to keep high.
    /// Before the accounting fix this divided by the simulation window
    /// only, so measured throughput pretended migration was free while
    /// `total_time()` charged it.
    pub throughput: f64,
    /// `None` on frozen (baseline) epochs and on the drain-out tail.
    pub refine: Option<EpochRefinement>,
}

/// Aggregate result of a closed-loop run.
#[derive(Debug, Clone)]
pub struct DynamicReport {
    pub stats: SimStats,
    pub epochs: Vec<EpochReport>,
    /// Total LP migrations across all refinement epochs.
    pub transfers: usize,
    /// Total wall-tick migration charge.
    pub migration_ticks: u64,
    /// Machine-load traces (populated if `sim.trace_every > 0`).
    pub load_traces: Vec<Trace>,
}

impl DynamicReport {
    /// Total simulation time including migration charges — the paper's
    /// headline metric.
    pub fn total_time(&self) -> u64 {
        self.stats.ticks + self.migration_ticks
    }

    /// Number of refinement epochs that actually ran.
    pub fn refinements(&self) -> usize {
        self.epochs.iter().filter(|e| e.refine.is_some()).count()
    }

    /// Refinement epochs whose potential *rose* — Thm 4.1 says this is
    /// impossible, so any non-zero count is a bug. `sim::fuzz` treats
    /// violations as first-class findings and the regression suite
    /// asserts the committed corpus keeps this at zero.
    pub fn descent_violations(&self) -> usize {
        self.epochs
            .iter()
            .filter_map(|e| e.refine.as_ref())
            .filter(|r| {
                r.potential_after > r.potential_before + 1e-9 * (1.0 + r.potential_before.abs())
            })
            .count()
    }

    /// Total coordinator sync traffic across every refinement epoch
    /// (`None` if no epoch used a message-passing backend).
    pub fn total_overhead(&self) -> Option<OverheadStats> {
        let mut total: Option<OverheadStats> = None;
        for r in self.epochs.iter().filter_map(|e| e.refine.as_ref()) {
            if let Some(o) = &r.overhead {
                total.get_or_insert_with(OverheadStats::default).add(o);
            }
        }
        total
    }

    /// Render the per-epoch stream as a table.
    pub fn epoch_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "epoch", "wall ticks", "mig", "events", "ev/tick", "rollbacks",
                "x-machine", "transfers", "potential",
            ],
        );
        for e in &self.epochs {
            let (transfers, potential) = match &e.refine {
                Some(r) => (
                    r.transfers.to_string(),
                    format!("{:.0} -> {:.0}", r.potential_before, r.potential_after),
                ),
                None => ("-".into(), "(frozen)".into()),
            };
            t.row(&[
                e.epoch.to_string(),
                format!("{}..{}", e.wall_tick_start, e.wall_tick_end),
                e.migration_ticks.to_string(),
                e.events_processed.to_string(),
                format!("{:.3}", e.throughput),
                e.rollbacks.to_string(),
                e.cross_machine_forwards.to_string(),
                transfers,
                potential,
            ]);
        }
        t
    }
}

/// The closed-loop driver. Borrows the (topology-)immutable LP graph;
/// owns a private weighted copy for the refinement side.
pub struct DynamicDriver<'g> {
    engine: SimEngine<'g>,
    lp_graph: Graph,
    machines: MachineConfig,
    estimator: WeightEstimator,
    options: DynamicOptions,
    epochs: Vec<EpochReport>,
    refinements: usize,
    transfers: usize,
    migration_ticks: u64,
    /// When attached, the distributed backend refines over this real
    /// multi-process TCP cluster instead of in-process actor threads.
    cluster: Option<ClusterLeader>,
}

impl<'g> DynamicDriver<'g> {
    pub fn new(
        graph: &'g Graph,
        machines: MachineConfig,
        initial: Partition,
        injections: Vec<Injection>,
        estimator: WeightEstimator,
        options: DynamicOptions,
    ) -> Self {
        let engine =
            SimEngine::new(graph, machines.clone(), initial, options.sim.clone(), injections);
        DynamicDriver {
            engine,
            lp_graph: graph.clone(),
            machines,
            estimator,
            options,
            epochs: Vec::new(),
            refinements: 0,
            transfers: 0,
            migration_ticks: 0,
            cluster: None,
        }
    }

    /// Route every distributed refinement over a connected TCP cluster
    /// (broadcasts the shared fixture to the workers first). Requires
    /// `options.backend == RefineBackend::Distributed`.
    pub fn attach_cluster(&mut self, cluster: ClusterLeader) -> Result<(), WireError> {
        assert_eq!(
            self.options.backend,
            RefineBackend::Distributed,
            "a TCP cluster needs the distributed backend"
        );
        if let Err(e) = cluster.setup(&self.lp_graph, &self.machines) {
            // Best-effort Goodbye so workers that did complete the
            // handshake exit now instead of waiting out EPOCH_WAIT.
            let _ = cluster.shutdown();
            return Err(e);
        }
        self.cluster = Some(cluster);
        Ok(())
    }

    pub fn engine(&self) -> &SimEngine<'g> {
        &self.engine
    }

    pub fn epochs(&self) -> &[EpochReport] {
        &self.epochs
    }

    /// Potential of `part` on the current (re-measured) LP graph, under
    /// the configured framework.
    fn potential_of(&self, part: &Partition) -> f64 {
        match self.options.framework {
            Framework::A => global_cost::c0(&self.lp_graph, &self.machines, part, self.options.mu),
            Framework::B => {
                global_cost::c0_tilde(&self.lp_graph, &self.machines, part, self.options.mu)
            }
        }
    }

    /// Measure → estimate → install → refine (warm start) → migrate.
    /// Only the TCP-cluster path can fail; on error the cluster is torn
    /// down first (Goodbye) so surviving workers exit immediately.
    fn refine_once(&mut self, counters: &EpochCounters) -> Result<EpochRefinement, WireError> {
        let raw = weights::measure_epoch(&self.engine, counters);
        let estimated = self.estimator.estimate(&raw);
        weights::install(&mut self.lp_graph, &estimated);

        let mut part = self.engine.partition().clone();
        part.rebuild_aggregates(&self.lp_graph);
        let imbalance_before = part.imbalance(&self.machines);

        let (potential_before, potential_after, transfers, converged, overhead, refined) =
            match self.options.backend {
                RefineBackend::Sequential => {
                    let mut refine = RefineEngine::new(
                        &self.lp_graph,
                        &self.machines,
                        part,
                        self.options.mu,
                        self.options.framework,
                    )
                    .with_migration_charge(self.options.migration_charge);
                    let before = refine.potential();
                    let report = refine.run(&RefineOptions::default());
                    (
                        before,
                        report.final_potential,
                        report.transfers,
                        report.converged,
                        None,
                        refine.into_partition(),
                    )
                }
                RefineBackend::Distributed => {
                    let before = self.potential_of(&part);
                    let report = if self.cluster.is_some() {
                        let result = self
                            .cluster
                            .as_mut()
                            .expect("checked above")
                            .refine(&self.lp_graph, &self.machines, part);
                        match result {
                            Ok(report) => report,
                            Err(e) => {
                                // Tear down first so surviving workers
                                // get a Goodbye and exit immediately
                                // instead of waiting out EPOCH_WAIT.
                                if let Some(cluster) = self.cluster.take() {
                                    let _ = cluster.shutdown();
                                }
                                return Err(e);
                            }
                        }
                    } else {
                        run_distributed(
                            Arc::new(self.lp_graph.clone()),
                            &self.machines,
                            part,
                            &DistributedOptions {
                                mu: self.options.mu,
                                framework: self.options.framework,
                                migration_charge: self.options.migration_charge,
                                ..Default::default()
                            },
                        )
                    };
                    let after = self.potential_of(&report.partition);
                    (
                        before,
                        after,
                        report.transfers,
                        report.converged,
                        Some(report.overhead),
                        report.partition,
                    )
                }
            };

        let imbalance_after = refined.imbalance(&self.machines);
        let charge = self.options.ticks_per_transfer * transfers as u64;
        self.refinements += 1;
        self.transfers += transfers;
        self.migration_ticks += charge;
        self.engine.set_partition(refined);
        Ok(EpochRefinement {
            potential_before,
            potential_after,
            transfers,
            migration_ticks: charge,
            migration_cost: self.options.migration_charge * transfers as f64,
            imbalance_before,
            imbalance_after,
            converged,
            overhead,
        })
    }

    /// Run one epoch: up to `epoch_ticks` of simulation, then (if work
    /// remains and rebalancing is enabled) one refinement pass. Returns
    /// `Ok(false)` once the workload drained or the tick cap was hit.
    /// Only a TCP-cluster refinement can return `Err`; without an
    /// attached cluster this is infallible.
    pub fn try_run_epoch(&mut self) -> Result<bool, WireError> {
        if self.engine.drained() || self.engine.stats().ticks >= self.options.sim.max_ticks {
            return Ok(false);
        }
        let tick_start = self.engine.stats().ticks;
        // Wall clock = engine clock + every migration stall so far; the
        // per-epoch wall windows must tile [0, total_time()] exactly.
        let wall_tick_start = tick_start + self.migration_ticks;
        let budget = if self.options.epoch_ticks == 0 {
            self.options.sim.max_ticks
        } else {
            self.options.epoch_ticks
        };
        // Epoch boundary in absolute ticks; `step_bounded` keeps
        // fast-forward jumps inside it so epoch windows are exact.
        let limit = tick_start.saturating_add(budget).min(self.options.sim.max_ticks);
        while self.engine.stats().ticks < limit && self.engine.step_bounded(limit) {}
        let counters = self.engine.take_epoch_counters();
        let tick_end = self.engine.stats().ticks;
        let more = !self.engine.drained() && tick_end < self.options.sim.max_ticks;

        let refine = if more
            && self.options.epoch_ticks > 0
            && (self.options.max_refinements == 0 || self.refinements < self.options.max_refinements)
        {
            Some(self.refine_once(&counters)?)
        } else {
            None
        };

        // The refinement that closed this epoch stalls the run for its
        // migration charge, so the epoch's wall window (and therefore
        // its measured throughput) includes the stall — consistent with
        // `total_time()`, which bills the same ticks.
        let migration_ticks = refine.as_ref().map_or(0, |r| r.migration_ticks);
        let wall_tick_end = tick_end + self.migration_ticks;
        debug_assert_eq!(
            wall_tick_end - wall_tick_start,
            (tick_end - tick_start) + migration_ticks,
            "wall window must be the sim window plus this epoch's stall"
        );
        let window = (wall_tick_end - wall_tick_start).max(1);
        self.epochs.push(EpochReport {
            epoch: self.epochs.len(),
            tick_start,
            tick_end,
            wall_tick_start,
            wall_tick_end,
            migration_ticks,
            events_processed: counters.events_total(),
            rollbacks: counters.rollbacks_total(),
            cross_machine_forwards: counters.cross_forwards_total(),
            throughput: counters.events_total() as f64 / window as f64,
            refine,
        });
        Ok(more)
    }

    /// Infallible [`DynamicDriver::try_run_epoch`]; panics on a TCP
    /// cluster failure (which cannot happen without an attached
    /// cluster — every in-process backend is infallible).
    pub fn run_epoch(&mut self) -> bool {
        self.try_run_epoch().unwrap_or_else(|e| panic!("distributed refinement failed: {e}"))
    }

    /// Run epochs until the workload drains (or `max_ticks`). Only a
    /// TCP-cluster refinement can return `Err` (after the cluster was
    /// torn down with a Goodbye so workers exit promptly).
    pub fn try_run(&mut self) -> Result<DynamicReport, WireError> {
        while self.try_run_epoch()? {}
        if let Some(cluster) = self.cluster.take() {
            // Graceful cluster teardown: workers exit on Goodbye.
            if let Err(e) = cluster.shutdown() {
                eprintln!("gtip net: cluster shutdown failed: {e}");
            }
        }
        let mut stats = self.engine.stats().clone();
        if !self.engine.drained() {
            stats.truncated = true;
        }
        Ok(DynamicReport {
            stats,
            epochs: self.epochs.clone(),
            transfers: self.transfers,
            migration_ticks: self.migration_ticks,
            load_traces: self.engine.load_traces().to_vec(),
        })
    }

    /// Infallible [`DynamicDriver::try_run`] for the in-process
    /// backends (panics on a TCP cluster failure).
    pub fn run(&mut self) -> DynamicReport {
        self.try_run().unwrap_or_else(|e| panic!("distributed refinement failed: {e}"))
    }
}

/// Run a full closed loop from an App.-A hop-growth initial partition
/// (unit weights) — the `gtip dynamic` entry point.
pub fn run_closed_loop(
    graph: &Graph,
    machines: &MachineConfig,
    injections: Vec<Injection>,
    estimator: WeightEstimator,
    options: &DynamicOptions,
    rng: &mut Pcg32,
) -> DynamicReport {
    let initial = grow_partition(graph, machines, rng);
    let mut driver = DynamicDriver::new(
        graph,
        machines.clone(),
        initial,
        injections,
        estimator,
        options.clone(),
    );
    driver.run()
}

/// Frozen-vs-rebalanced comparison on an identical graph, workload and
/// initial partition — the headline §6.1 experiment.
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub frozen: DynamicReport,
    pub rebalanced: DynamicReport,
}

impl CompareReport {
    /// `frozen time / rebalanced time` (> 1 means rebalancing won).
    /// Both arms draining in zero ticks (an empty workload) is a tie:
    /// 1.0, not the 0.0 the naive `0 / max(1)` would report — and the
    /// denominator clamp can only engage in that same degenerate case,
    /// so it never silently skews a real comparison.
    pub fn speedup(&self) -> f64 {
        CompareReport::speedup_of(self.frozen.total_time(), self.rebalanced.total_time())
    }

    /// The speedup definition on bare totals — for callers (e.g. the
    /// churn sweep) that hold one frozen run against many rebalanced
    /// arms without assembling a `CompareReport` per pair.
    pub fn speedup_of(frozen_time: u64, rebalanced_time: u64) -> f64 {
        if frozen_time == 0 && rebalanced_time == 0 {
            return 1.0;
        }
        frozen_time as f64 / rebalanced_time.max(1) as f64
    }
}

/// Run both arms. The frozen arm keeps `initial` for the whole run; the
/// rebalanced arm closes the loop with `estimator` every `epoch_ticks`.
pub fn compare_frozen_vs_rebalanced(
    graph: &Graph,
    machines: &MachineConfig,
    initial: &Partition,
    injections: &[Injection],
    estimator: WeightEstimator,
    options: &DynamicOptions,
) -> CompareReport {
    let frozen_options = DynamicOptions { epoch_ticks: 0, ..options.clone() };
    let frozen = DynamicDriver::new(
        graph,
        machines.clone(),
        initial.clone(),
        injections.to_vec(),
        WeightEstimator::instantaneous(),
        frozen_options,
    )
    .run_owned();
    let rebalanced = DynamicDriver::new(
        graph,
        machines.clone(),
        initial.clone(),
        injections.to_vec(),
        estimator,
        options.clone(),
    )
    .run_owned();
    CompareReport { frozen, rebalanced }
}

impl<'g> DynamicDriver<'g> {
    /// `run()` for by-value call chains.
    pub fn run_owned(mut self) -> DynamicReport {
        self.run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::preferential_attachment;
    use crate::sim::scenario::{Scenario, ScenarioKind, ScenarioOptions};

    fn setup(seed: u64) -> (Graph, MachineConfig, Scenario) {
        let mut rng = Pcg32::new(seed);
        let g = preferential_attachment(120, 2, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let scenario = Scenario::build(
            ScenarioKind::HotspotShift,
            &g,
            &ScenarioOptions { threads: 60, horizon_ticks: 900, ..Default::default() },
            &mut rng,
        );
        (g, machines, scenario)
    }

    fn options(epoch_ticks: u64) -> DynamicOptions {
        DynamicOptions {
            sim: SimOptions { max_ticks: 200_000, ..Default::default() },
            epoch_ticks,
            ..Default::default()
        }
    }

    #[test]
    fn closed_loop_runs_refines_and_reports() {
        let (g, machines, scenario) = setup(1);
        let mut rng = Pcg32::new(2);
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &options(150),
            &mut rng,
        );
        assert!(!report.stats.truncated, "truncated: {:?}", report.stats);
        assert!(report.refinements() > 0, "no refinement epochs ran");
        assert_eq!(report.epochs.last().map(|e| e.tick_end), Some(report.stats.ticks));
        // Every refinement descends its potential (Thm 4.1).
        for e in &report.epochs {
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after <= r.potential_before + 1e-9,
                    "epoch {}: potential rose {} -> {}",
                    e.epoch,
                    r.potential_before,
                    r.potential_after
                );
                assert!(r.converged);
            }
        }
        // Epoch windows tile the run.
        for pair in report.epochs.windows(2) {
            assert_eq!(pair[0].tick_end, pair[1].tick_start);
        }
    }

    #[test]
    fn frozen_mode_never_refines() {
        let (g, machines, scenario) = setup(3);
        let mut rng = Pcg32::new(4);
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &options(0),
            &mut rng,
        );
        assert_eq!(report.refinements(), 0);
        assert_eq!(report.transfers, 0);
        assert!(!report.stats.truncated);
        assert_eq!(report.epochs.len(), 1, "frozen run is one long epoch");
    }

    #[test]
    fn migration_charges_accumulate() {
        let (g, machines, scenario) = setup(5);
        let mut rng = Pcg32::new(6);
        let mut opts = options(150);
        opts.ticks_per_transfer = 3;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert_eq!(report.migration_ticks, 3 * report.transfers as u64);
        assert_eq!(report.total_time(), report.stats.ticks + report.migration_ticks);
        let per_epoch: u64 =
            report.epochs.iter().filter_map(|e| e.refine.as_ref()).map(|r| r.migration_ticks).sum();
        assert_eq!(per_epoch, report.migration_ticks);
    }

    /// The migration-time accounting seam (regression): epoch *wall*
    /// windows must tile `[0, total_time()]` exactly — each window is
    /// the sim window plus that epoch's migration stall — and
    /// throughput must divide by the stalled window, so per-epoch
    /// metrics and the headline metric bill migration identically.
    #[test]
    fn wall_windows_tile_total_time_and_throughput_bills_migration() {
        let (g, machines, scenario) = setup(11);
        let mut rng = Pcg32::new(12);
        let mut opts = options(150);
        opts.ticks_per_transfer = 4;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(report.migration_ticks > 0, "fixture produced no migration charge");
        assert_eq!(report.epochs.first().map(|e| e.wall_tick_start), Some(0));
        for pair in report.epochs.windows(2) {
            assert_eq!(pair[0].wall_tick_end, pair[1].wall_tick_start, "wall windows must tile");
            assert_eq!(pair[0].tick_end, pair[1].tick_start, "sim windows must tile");
        }
        assert_eq!(
            report.epochs.last().map(|e| e.wall_tick_end),
            Some(report.total_time()),
            "wall clock must end at the headline total"
        );
        for e in &report.epochs {
            assert_eq!(
                e.wall_tick_end - e.wall_tick_start,
                (e.tick_end - e.tick_start) + e.migration_ticks,
                "epoch {}: wall window != sim window + stall",
                e.epoch
            );
            assert_eq!(e.migration_ticks, e.refine.as_ref().map_or(0, |r| r.migration_ticks));
            let wall_window = (e.wall_tick_end - e.wall_tick_start).max(1);
            assert_eq!(
                e.throughput.to_bits(),
                (e.events_processed as f64 / wall_window as f64).to_bits(),
                "epoch {}: throughput must divide by the stalled window",
                e.epoch
            );
        }
        // total_time, windows, and throughput pinned together.
        let summed: u64 = report
            .epochs
            .iter()
            .map(|e| e.wall_tick_end - e.wall_tick_start)
            .sum();
        assert_eq!(summed, report.total_time());
    }

    /// `CompareReport::speedup` on the degenerate empty workload (both
    /// arms drain in zero ticks) is defined as 1.0, not 0.0.
    #[test]
    fn speedup_of_empty_workload_is_one() {
        let (g, machines, _) = setup(13);
        let mut rng = Pcg32::new(14);
        let initial = grow_partition(&g, &machines, &mut rng);
        let report = compare_frozen_vs_rebalanced(
            &g,
            &machines,
            &initial,
            &[], // no injections: both arms drain instantly
            WeightEstimator::instantaneous(),
            &options(150),
        );
        assert_eq!(report.frozen.total_time(), 0);
        assert_eq!(report.rebalanced.total_time(), 0);
        assert_eq!(report.speedup(), 1.0);
        // The bare-totals helper agrees with the method everywhere.
        assert_eq!(CompareReport::speedup_of(0, 0), 1.0);
        assert_eq!(CompareReport::speedup_of(100, 50), 2.0);
        assert_eq!(CompareReport::speedup_of(7, 0), 7.0);
    }

    /// The in-game charge prices moves inside the closed loop: every
    /// refinement epoch satisfies the augmented-descent guarantee
    /// `potential_after + migration_cost <= potential_before`, the
    /// per-epoch churn bound `transfers <= ΔΦ / (2·c_mig)` (framework A
    /// default), and `migration_cost` bills exactly charge × transfers.
    /// (The prohibitive-charge freeze and the free-vs-charged triple
    /// are covered end-to-end by
    /// `integration_dynamic::in_game_charge_reduces_churn_end_to_end`.)
    #[test]
    fn in_game_charge_damps_closed_loop_churn() {
        let (g, machines, scenario) = setup(15);
        let mut rng = Pcg32::new(16);
        let mut opts = options(150);
        opts.migration_charge = 50.0;
        let charged = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(charged.refinements() > 0, "loop never refined; test is vacuous");
        for e in &charged.epochs {
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after + r.migration_cost
                        <= r.potential_before + 1e-9 * (1.0 + r.potential_before.abs()),
                    "epoch {}: augmented descent violated: {} + {} > {}",
                    e.epoch,
                    r.potential_after,
                    r.migration_cost,
                    r.potential_before
                );
                assert_eq!(r.migration_cost, 50.0 * r.transfers as f64);
                // Churn bound theorem: each move drops the raw
                // potential by >= 2*c_mig under framework A.
                assert!(
                    r.transfers as f64
                        <= (r.potential_before - r.potential_after) / (2.0 * 50.0)
                            * (1.0 + 1e-9)
                            + 1e-9,
                    "epoch {}: churn bound violated",
                    e.epoch
                );
            }
        }
    }

    #[test]
    fn max_refinements_caps_the_loop() {
        let (g, machines, scenario) = setup(7);
        let mut rng = Pcg32::new(8);
        let mut opts = options(100);
        opts.max_refinements = 2;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(report.refinements() <= 2);
        assert!(!report.stats.truncated);
    }

    #[test]
    fn distributed_backend_matches_sequential_loop() {
        let (g, machines, scenario) = setup(9);
        let mut opts = options(200);
        let mut rng = Pcg32::new(10);
        let initial = grow_partition(&g, &machines, &mut rng);

        let seq = DynamicDriver::new(
            &g,
            machines.clone(),
            initial.clone(),
            scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            opts.clone(),
        )
        .run_owned();

        opts.backend = RefineBackend::Distributed;
        let dist = DynamicDriver::new(
            &g,
            machines.clone(),
            initial,
            scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            opts,
        )
        .run_owned();

        // Same deterministic turn order => the whole closed loop agrees.
        assert_eq!(seq.stats.ticks, dist.stats.ticks);
        assert_eq!(seq.transfers, dist.transfers);
        assert_eq!(seq.epochs.len(), dist.epochs.len());
        // Only the message-passing backend accumulates sync overhead.
        assert!(seq.total_overhead().is_none());
        let overhead = dist.total_overhead().expect("distributed epochs measure overhead");
        assert!(overhead.total_messages() > 0);
        for (a, b) in seq.epochs.iter().zip(&dist.epochs) {
            match (&a.refine, &b.refine) {
                (Some(ra), Some(rb)) => {
                    assert_eq!(ra.transfers, rb.transfers);
                    assert!((ra.potential_after - rb.potential_after).abs() < 1e-6);
                }
                (None, None) => {}
                other => panic!("refinement schedule diverged: {other:?}"),
            }
        }
    }

    #[test]
    fn ewma_smooths_toward_new_signal() {
        let raw1 = MeasuredWeights {
            node_weights: vec![10.0, 0.0],
            edge_weights: vec![(0, 1, 4.0)],
        };
        let raw2 = MeasuredWeights {
            node_weights: vec![0.0, 10.0],
            edge_weights: vec![(0, 1, 0.0)],
        };
        let mut est = WeightEstimator::ewma(0.5);
        let first = est.estimate(&raw1);
        assert_eq!(first.node_weights, vec![10.0, 0.0], "first call primes");
        let second = est.estimate(&raw2);
        // Halfway between the two signals.
        assert!((second.node_weights[0] - 5.0).abs() < 1e-12);
        assert!((second.node_weights[1] - 5.0).abs() < 1e-12);
        assert!((second.edge_weights[0].2 - 2.0).abs() < 1e-12);
        // Repeated exposure converges to the new signal.
        for _ in 0..20 {
            est.estimate(&raw2);
        }
        let converged = est.estimate(&raw2);
        assert!((converged.node_weights[1] - 10.0).abs() < 1e-2);
    }

    #[test]
    fn hysteresis_holds_output_inside_deadband() {
        let raw = MeasuredWeights {
            node_weights: vec![10.0],
            edge_weights: vec![(0, 1, 10.0)],
        };
        let wiggle = MeasuredWeights {
            node_weights: vec![10.5],
            edge_weights: vec![(0, 1, 10.5)],
        };
        let jump = MeasuredWeights {
            node_weights: vec![30.0],
            edge_weights: vec![(0, 1, 30.0)],
        };
        let mut est = WeightEstimator::hysteresis(1.0, 0.25);
        let a = est.estimate(&raw);
        assert_eq!(a.node_weights[0], 10.0);
        // 5% wiggle stays inside the 25% dead band: output frozen.
        let b = est.estimate(&wiggle);
        assert_eq!(b.node_weights[0], 10.0);
        assert_eq!(b.edge_weights[0].2, 10.0);
        // A 3x jump breaks out.
        let c = est.estimate(&jump);
        assert_eq!(c.node_weights[0], 30.0);
        assert_eq!(c.edge_weights[0].2, 30.0);
    }

    #[test]
    fn charge_transfers_derives_the_in_game_price() {
        let opts = DynamicOptions::default().charge_transfers(3, 2.5);
        assert_eq!(opts.ticks_per_transfer, 3);
        assert_eq!(opts.migration_charge, 7.5);
        let free = DynamicOptions::default().charge_transfers(5, 0.0);
        assert_eq!(free.ticks_per_transfer, 5);
        assert_eq!(free.migration_charge, 0.0);
    }

    #[test]
    fn estimator_and_backend_parse_from_strings() {
        assert_eq!("ewma".parse::<EstimatorKind>().unwrap(), EstimatorKind::Ewma);
        assert_eq!(
            "hysteresis".parse::<EstimatorKind>().unwrap(),
            EstimatorKind::Hysteresis
        );
        assert!("nope".parse::<EstimatorKind>().is_err());
        assert_eq!("sequential".parse::<RefineBackend>().unwrap(), RefineBackend::Sequential);
        assert_eq!("dist".parse::<RefineBackend>().unwrap(), RefineBackend::Distributed);
        assert!("p2p".parse::<RefineBackend>().is_err());
    }
}

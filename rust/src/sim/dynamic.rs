//! Closed-loop dynamic rebalancing (§6.1) — the paper's *title*
//! scenario, end to end.
//!
//! [`DynamicDriver`] alternates **simulation epochs** with **refinement
//! epochs**: run the optimistic PDES engine for `epoch_ticks` wall
//! ticks, harvest the per-LP measured loads of the window (events
//! processed, rollbacks, per-edge forward traffic — see
//! [`EpochCounters`]), turn them into fresh node/edge weights through a
//! pluggable [`WeightEstimator`], re-run the game-theoretic refinement
//! *warm-started from the current partition* (sequentially or through
//! the distributed machine-actor coordinator, see [`RefineBackend`]),
//! migrate the LPs on the live engine, and record an [`EpochReport`].
//!
//! Differences from the one-shot `sim::driver` loop kept for the Fig.
//! 7–10 harnesses: epoch-boundary (not modulo-tick) scheduling, windowed
//! activity measurement instead of instantaneous queue lengths only,
//! estimator smoothing/hysteresis to damp migration churn (cf. the
//! self-clustering partitioner of arXiv:1610.01295), a selectable
//! distributed backend, and a per-epoch report stream capturing the
//! potential descent of every refinement.

use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::net::ClusterLeader;
use crate::coordinator::{
    run_distributed, run_distributed_hierarchical, DistributedOptions, OverheadStats, WireError,
};
use crate::game::cost::Framework;
use crate::game::hierarchy::{refine_hierarchical, RackLayout};
use crate::game::refine::{rehome_assignment, RefineEngine, RefineOptions};
use crate::graph::Graph;
use crate::partition::initial::grow_partition;
use crate::partition::{global_cost, MachineConfig, MachineId, Partition};
use crate::sim::engine::{EpochCounters, Injection, SimEngine, SimOptions, SimStats};
use crate::sim::snapshot::{EstimatorState, Snapshot};
use crate::sim::weights::{self, MeasuredWeights};
use crate::util::rng::Pcg32;
use crate::util::stats::Trace;
use crate::util::table::Table;

/// How measured loads become refinement weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorKind {
    /// Use the latest window's measurement as-is.
    Instantaneous,
    /// Exponentially-weighted moving average across windows.
    Ewma,
    /// EWMA plus a relative dead band: the emitted weight only moves
    /// when the smoothed estimate drifts far enough, damping migration
    /// churn between epochs.
    Hysteresis,
}

impl std::str::FromStr for EstimatorKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "instant" | "instantaneous" => Ok(EstimatorKind::Instantaneous),
            "ewma" => Ok(EstimatorKind::Ewma),
            "hyst" | "hysteresis" => Ok(EstimatorKind::Hysteresis),
            other => Err(format!(
                "unknown estimator {other:?} (expected instant|ewma|hysteresis)"
            )),
        }
    }
}

impl std::fmt::Display for EstimatorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EstimatorKind::Instantaneous => "instant",
            EstimatorKind::Ewma => "ewma",
            EstimatorKind::Hysteresis => "hysteresis",
        })
    }
}

/// Stateful weight estimator fed one [`MeasuredWeights`] per epoch.
#[derive(Debug, Clone)]
pub struct WeightEstimator {
    kind: EstimatorKind,
    /// EWMA smoothing factor in `(0, 1]` (1 = no memory).
    alpha: f64,
    /// Relative dead band of the hysteresis variant.
    deadband: f64,
    node_state: Vec<f64>,
    edge_state: Vec<f64>,
    node_out: Vec<f64>,
    edge_out: Vec<f64>,
    primed: bool,
}

impl WeightEstimator {
    pub fn new(kind: EstimatorKind, alpha: f64, deadband: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of (0, 1]");
        assert!(deadband >= 0.0, "negative dead band");
        WeightEstimator {
            kind,
            alpha,
            deadband,
            node_state: Vec::new(),
            edge_state: Vec::new(),
            node_out: Vec::new(),
            edge_out: Vec::new(),
            primed: false,
        }
    }

    /// Pass-through estimator.
    pub fn instantaneous() -> Self {
        WeightEstimator::new(EstimatorKind::Instantaneous, 1.0, 0.0)
    }

    /// EWMA-smoothed estimator.
    pub fn ewma(alpha: f64) -> Self {
        WeightEstimator::new(EstimatorKind::Ewma, alpha, 0.0)
    }

    /// EWMA plus relative dead band.
    pub fn hysteresis(alpha: f64, deadband: f64) -> Self {
        WeightEstimator::new(EstimatorKind::Hysteresis, alpha, deadband)
    }

    /// Default parameters per kind (used by the CLI).
    pub fn of_kind(kind: EstimatorKind) -> Self {
        match kind {
            EstimatorKind::Instantaneous => WeightEstimator::instantaneous(),
            EstimatorKind::Ewma => WeightEstimator::ewma(0.5),
            EstimatorKind::Hysteresis => WeightEstimator::hysteresis(0.5, 0.25),
        }
    }

    pub fn kind(&self) -> EstimatorKind {
        self.kind
    }

    /// Smoothing memory for a checkpoint (`None` until the first
    /// window primes it; configuration is not state and is rebuilt
    /// from options on restore).
    pub fn export_state(&self) -> Option<EstimatorState> {
        if !self.primed {
            return None;
        }
        Some(EstimatorState {
            node_state: self.node_state.clone(),
            edge_state: self.edge_state.clone(),
            node_out: self.node_out.clone(),
            edge_out: self.edge_out.clone(),
            primed: self.primed,
        })
    }

    /// Adopt checkpointed smoothing memory verbatim (`None` resets to
    /// the unprimed initial state).
    pub fn import_state(&mut self, state: Option<EstimatorState>) {
        match state {
            None => {
                self.node_state.clear();
                self.edge_state.clear();
                self.node_out.clear();
                self.edge_out.clear();
                self.primed = false;
            }
            Some(s) => {
                self.node_state = s.node_state;
                self.edge_state = s.edge_state;
                self.node_out = s.node_out;
                self.edge_out = s.edge_out;
                self.primed = s.primed;
            }
        }
    }

    /// Fold one window's raw measurement into the estimate and return
    /// the weights to hand to the refinement engine.
    pub fn estimate(&mut self, raw: &MeasuredWeights) -> MeasuredWeights {
        if self.kind == EstimatorKind::Instantaneous {
            return raw.clone();
        }
        if !self.primed {
            self.node_state = raw.node_weights.clone();
            self.edge_state = raw.edge_weights.iter().map(|&(_, _, c)| c).collect();
            self.node_out = self.node_state.clone();
            self.edge_out = self.edge_state.clone();
            self.primed = true;
        } else {
            assert_eq!(self.node_state.len(), raw.node_weights.len(), "graph changed shape");
            assert_eq!(self.edge_state.len(), raw.edge_weights.len(), "graph changed shape");
            for (s, &x) in self.node_state.iter_mut().zip(&raw.node_weights) {
                *s = self.alpha * x + (1.0 - self.alpha) * *s;
            }
            for (s, &(_, _, c)) in self.edge_state.iter_mut().zip(&raw.edge_weights) {
                *s = self.alpha * c + (1.0 - self.alpha) * *s;
            }
            match self.kind {
                EstimatorKind::Ewma => {
                    self.node_out.copy_from_slice(&self.node_state);
                    self.edge_out.copy_from_slice(&self.edge_state);
                }
                EstimatorKind::Hysteresis => {
                    let band = self.deadband;
                    for (o, &s) in self.node_out.iter_mut().zip(&self.node_state) {
                        if (s - *o).abs() > band * 1.0f64.max(o.abs()) {
                            *o = s;
                        }
                    }
                    for (o, &s) in self.edge_out.iter_mut().zip(&self.edge_state) {
                        if (s - *o).abs() > band * 1.0f64.max(o.abs()) {
                            *o = s;
                        }
                    }
                }
                EstimatorKind::Instantaneous => unreachable!(),
            }
        }
        MeasuredWeights {
            node_weights: self.node_out.clone(),
            edge_weights: raw
                .edge_weights
                .iter()
                .zip(&self.edge_out)
                .map(|(&(u, v, _), &c)| (u, v, c))
                .collect(),
        }
    }
}

/// Which refinement implementation closes the loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefineBackend {
    /// In-process [`RefineEngine`] (fast path).
    Sequential,
    /// One-thread-per-machine actor protocol
    /// ([`run_distributed`]) — produces the identical equilibrium (same
    /// deterministic turn order) while measuring the O(K) sync traffic.
    Distributed,
}

impl std::str::FromStr for RefineBackend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "seq" | "sequential" => Ok(RefineBackend::Sequential),
            "dist" | "distributed" | "coordinator" => Ok(RefineBackend::Distributed),
            other => Err(format!(
                "unknown backend {other:?} (expected sequential|distributed)"
            )),
        }
    }
}

impl std::fmt::Display for RefineBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RefineBackend::Sequential => "sequential",
            RefineBackend::Distributed => "distributed",
        })
    }
}

/// Options of the closed loop.
#[derive(Debug, Clone)]
pub struct DynamicOptions {
    pub sim: SimOptions,
    /// Wall ticks per simulation epoch; 0 freezes the initial partition
    /// (the static baseline).
    pub epoch_ticks: u64,
    pub framework: Framework,
    /// Relative rollback-delay weight μ.
    pub mu: f64,
    pub backend: RefineBackend,
    /// Wall-tick charge per executed LP migration (the paper ignores
    /// migration cost; default 0).
    pub ticks_per_transfer: u64,
    /// Per-move surcharge `c_mig` priced *inside* the refinement game
    /// (augmented dissatisfaction, DESIGN.md §9): a transfer is only
    /// accepted when its raw cost gain exceeds this many cost units.
    /// Use [`DynamicOptions::charge_transfers`] to derive it from
    /// `ticks_per_transfer` so the game prices exactly what the report
    /// bills. 0 reproduces the paper's charge-free game.
    pub migration_charge: f64,
    /// Cap on refinement epochs (0 = unlimited).
    pub max_refinements: usize,
    /// When set, every epoch-boundary [`Snapshot`] is also written
    /// here (`epoch-NNNN.snap`, numbered by the *cumulative* epoch
    /// counter so a restored run never overwrites the original run's
    /// files; plus `recovery-NNNN.snap` after each worker death and
    /// `admit-NNNN.snap` after each admission), so an operator can
    /// inspect or `--restore` them. The in-memory checkpoint that
    /// powers live recovery is kept whenever a TCP cluster is
    /// attached, with or without this directory.
    pub checkpoint_dir: Option<PathBuf>,
    /// Two-level hierarchy (DESIGN.md §12): when set, every refinement
    /// epoch plays the outer rack-quotient game then the scoped inner
    /// per-rack games instead of the flat K-machine game. `None` (the
    /// default) keeps the flat game. The layout must cover exactly the
    /// starting fleet; singleton racks reproduce the flat equilibrium
    /// bit-for-bit.
    pub racks: Option<RackLayout>,
}

impl Default for DynamicOptions {
    fn default() -> Self {
        DynamicOptions {
            sim: SimOptions::default(),
            epoch_ticks: 200,
            framework: Framework::A,
            mu: 8.0,
            backend: RefineBackend::Sequential,
            ticks_per_transfer: 0,
            migration_charge: 0.0,
            max_refinements: 0,
            checkpoint_dir: None,
            racks: None,
        }
    }
}

impl DynamicOptions {
    /// Bill each transfer `ticks` wall ticks in the report AND price it
    /// at `c_mig = ticks · tick_value` cost units inside the game, so
    /// refinement only moves an LP when its modeled gain beats what the
    /// migration will cost the run. `tick_value` converts wall ticks to
    /// cost units (1.0 when node weights are events-per-window, the
    /// closed loop's default measurement).
    pub fn charge_transfers(mut self, ticks: u64, tick_value: f64) -> Self {
        assert!(tick_value >= 0.0 && tick_value.is_finite(), "tick value must be finite and >= 0");
        self.ticks_per_transfer = ticks;
        self.migration_charge = ticks as f64 * tick_value;
        self
    }
}

/// What one refinement epoch did.
#[derive(Debug, Clone)]
pub struct EpochRefinement {
    /// Potential on the re-measured weights *before* refining (warm
    /// start = current partition).
    pub potential_before: f64,
    /// Potential at the refined equilibrium. Never exceeds
    /// `potential_before` (Thm 4.1 descent).
    pub potential_after: f64,
    /// LP migrations executed.
    pub transfers: usize,
    /// Wall-tick migration charge of this epoch.
    pub migration_ticks: u64,
    /// In-game migration spend of this epoch: `c_mig · transfers`, in
    /// cost units. `potential_after + migration_cost ≤ potential_before`
    /// is the augmented-descent guarantee (DESIGN.md §9).
    pub migration_cost: f64,
    pub imbalance_before: f64,
    pub imbalance_after: f64,
    /// Whether refinement reached a Nash equilibrium (vs the cap).
    pub converged: bool,
    /// Measured coordinator sync traffic of this epoch (exact wire
    /// bytes) — `None` on the sequential backend, which sends nothing.
    pub overhead: Option<OverheadStats>,
}

/// What a worker-death recovery did (DESIGN.md §10): which machines
/// were lost, how the fleet shrank, and how many orphaned LPs were
/// re-homed onto the survivors before the epoch's refinement re-ran.
#[derive(Debug, Clone)]
pub struct RecoveryRecord {
    /// Machines diagnosed dead, in the logical numbering the cluster
    /// used when each one died (a second death during the retry is
    /// recorded in the already-compacted numbering).
    pub dead_machines: Vec<MachineId>,
    /// Fleet size when the epoch started.
    pub machines_before: usize,
    /// Fleet size after the last recovery round of the epoch.
    pub machines_after: usize,
    /// LPs that lived on dead machines and were re-homed.
    pub rehomed_lps: usize,
}

/// What a worker admission did — the [`RecoveryRecord`] counterpart
/// for the grow direction (DESIGN.md §10): which wire id joined, the
/// logical slot it was inserted at, and how the fleet grew. The
/// joiner starts with zero LPs; the next refinement epoch migrates
/// load toward it (Thm 4.1 descent holds from any feasible start).
#[derive(Debug, Clone)]
pub struct AdmissionRecord {
    /// The joiner's immutable wire id (its `--machine-id`).
    pub joined_wire_id: MachineId,
    /// The logical machine slot the joiner was inserted at (wire ids
    /// stay ascending, so members to its right shifted up by one).
    pub joined_machine: MachineId,
    /// Fleet size before the admission.
    pub machines_before: usize,
    /// Fleet size after (always `machines_before + 1`).
    pub machines_after: usize,
    /// The joiner's self-reported relative speed (1.0 = an average
    /// member of the original fleet), before renormalization.
    pub speed: f64,
}

/// Per-epoch record of the closed loop.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub epoch: usize,
    /// Simulation-tick window (engine clock; migration stalls excluded).
    pub tick_start: u64,
    pub tick_end: u64,
    /// Wall-clock window including migration stalls: `wall_tick_start`
    /// is `tick_start` plus every earlier epoch's migration charge, and
    /// `wall_tick_end` additionally includes *this* epoch's charge —
    /// epoch wall windows tile `[0, DynamicReport::total_time()]`
    /// exactly, so per-epoch weights and throughput bill migration time
    /// the same way the headline metric does.
    pub wall_tick_start: u64,
    pub wall_tick_end: u64,
    /// Wall-tick migration charge of this epoch's refinement (0 when
    /// the epoch did not refine).
    pub migration_ticks: u64,
    /// Events completed during the window.
    pub events_processed: u64,
    /// Rollback episodes during the window.
    pub rollbacks: u64,
    /// Cross-machine forwards during the window.
    pub cross_machine_forwards: u64,
    /// Events per *wall* tick over the window, migration stall
    /// included — the throughput the rebalancer tries to keep high.
    /// Before the accounting fix this divided by the simulation window
    /// only, so measured throughput pretended migration was free while
    /// `total_time()` charged it.
    pub throughput: f64,
    /// `None` on frozen (baseline) epochs and on the drain-out tail.
    pub refine: Option<EpochRefinement>,
    /// Set when one or more workers died during this epoch's
    /// refinement and the run restored from the last epoch-boundary
    /// checkpoint instead of unwinding (DESIGN.md §10).
    pub recovery: Option<RecoveryRecord>,
    /// Set when a queued joiner was admitted at this epoch's boundary
    /// and the fleet grew to K+1 before the epoch's refinement ran.
    pub admission: Option<AdmissionRecord>,
    /// Rack count of the hierarchy the refinement played (DESIGN.md
    /// §12); 0 when the epoch ran the flat game.
    pub racks: usize,
}

/// Aggregate result of a closed-loop run.
#[derive(Debug, Clone)]
pub struct DynamicReport {
    pub stats: SimStats,
    pub epochs: Vec<EpochReport>,
    /// Total LP migrations across all refinement epochs.
    pub transfers: usize,
    /// Total wall-tick migration charge.
    pub migration_ticks: u64,
    /// Machine-load traces (populated if `sim.trace_every > 0`).
    pub load_traces: Vec<Trace>,
}

impl DynamicReport {
    /// Total simulation time including migration charges — the paper's
    /// headline metric.
    pub fn total_time(&self) -> u64 {
        self.stats.ticks + self.migration_ticks
    }

    /// Number of refinement epochs that actually ran.
    pub fn refinements(&self) -> usize {
        self.epochs.iter().filter(|e| e.refine.is_some()).count()
    }

    /// Number of epochs that survived a worker death by restoring
    /// from the last checkpoint.
    pub fn recoveries(&self) -> usize {
        self.epochs.iter().filter(|e| e.recovery.is_some()).count()
    }

    /// Number of epochs that grew the fleet by admitting a joiner at
    /// their boundary.
    pub fn admissions(&self) -> usize {
        self.epochs.iter().filter(|e| e.admission.is_some()).count()
    }

    /// Refinement epochs whose potential *rose* — Thm 4.1 says this is
    /// impossible, so any non-zero count is a bug. `sim::fuzz` treats
    /// violations as first-class findings and the regression suite
    /// asserts the committed corpus keeps this at zero.
    pub fn descent_violations(&self) -> usize {
        self.epochs
            .iter()
            .filter_map(|e| e.refine.as_ref())
            .filter(|r| {
                r.potential_after > r.potential_before + 1e-9 * (1.0 + r.potential_before.abs())
            })
            .count()
    }

    /// Total coordinator sync traffic across every refinement epoch
    /// (`None` if no epoch used a message-passing backend).
    pub fn total_overhead(&self) -> Option<OverheadStats> {
        let mut total: Option<OverheadStats> = None;
        for r in self.epochs.iter().filter_map(|e| e.refine.as_ref()) {
            if let Some(o) = &r.overhead {
                total.get_or_insert_with(OverheadStats::default).add(o);
            }
        }
        total
    }

    /// Render the per-epoch stream as a table.
    pub fn epoch_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "epoch", "wall ticks", "mig", "events", "ev/tick", "rollbacks",
                "x-machine", "transfers", "potential",
            ],
        );
        for e in &self.epochs {
            let (transfers, potential) = match &e.refine {
                Some(r) => (
                    r.transfers.to_string(),
                    format!("{:.0} -> {:.0}", r.potential_before, r.potential_after),
                ),
                None => ("-".into(), "(frozen)".into()),
            };
            t.row(&[
                e.epoch.to_string(),
                format!("{}..{}", e.wall_tick_start, e.wall_tick_end),
                e.migration_ticks.to_string(),
                e.events_processed.to_string(),
                format!("{:.3}", e.throughput),
                e.rollbacks.to_string(),
                e.cross_machine_forwards.to_string(),
                transfers,
                potential,
            ]);
        }
        t
    }
}

/// The closed-loop driver. Borrows the (topology-)immutable LP graph;
/// owns a private weighted copy for the refinement side.
pub struct DynamicDriver<'g> {
    /// The immutable LP topology the engine borrows — kept so the
    /// engine can be *rebuilt* from a checkpoint during recovery.
    graph: &'g Graph,
    engine: SimEngine<'g>,
    lp_graph: Graph,
    machines: MachineConfig,
    estimator: WeightEstimator,
    options: DynamicOptions,
    epochs: Vec<EpochReport>,
    /// Epochs completed *before* this driver existed (non-zero only
    /// when restored from a snapshot). Epoch reports renumber from 0
    /// per run, but checkpoint filenames and the `epoch` counter
    /// stored in snapshots use `epoch_base + epochs.len()`, so a
    /// resumed run sharing `checkpoint_dir` with the original never
    /// overwrites the original's files.
    epoch_base: u64,
    /// Recoveries taken this run — names `recovery-NNNN.snap` so a
    /// second recovery does not overwrite the first's replay point.
    recovery_ordinal: usize,
    /// Admissions granted this run — names `admit-NNNN.snap`.
    admission_ordinal: usize,
    refinements: usize,
    transfers: usize,
    migration_ticks: u64,
    /// When attached, the distributed backend refines over this real
    /// multi-process TCP cluster instead of in-process actor threads.
    cluster: Option<ClusterLeader>,
    /// Encoded bytes of the last epoch-boundary [`Snapshot`] —
    /// restored from on worker death. Kept whenever a cluster is
    /// attached or `checkpoint_dir` is set.
    last_checkpoint: Option<Vec<u8>>,
}

impl<'g> DynamicDriver<'g> {
    pub fn new(
        graph: &'g Graph,
        machines: MachineConfig,
        initial: Partition,
        injections: Vec<Injection>,
        estimator: WeightEstimator,
        options: DynamicOptions,
    ) -> Self {
        let engine =
            SimEngine::new(graph, machines.clone(), initial, options.sim.clone(), injections);
        DynamicDriver {
            graph,
            engine,
            lp_graph: graph.clone(),
            machines,
            estimator,
            options,
            epochs: Vec::new(),
            epoch_base: 0,
            recovery_ordinal: 0,
            admission_ordinal: 0,
            refinements: 0,
            transfers: 0,
            migration_ticks: 0,
            cluster: None,
            last_checkpoint: None,
        }
    }

    /// Resume a run from a decoded epoch-boundary [`Snapshot`] — the
    /// `gtip dynamic --restore` entry point. `graph` must have the
    /// snapshot's topology (use [`Snapshot::build_graph`]); the sim
    /// options stored in the snapshot override `options.sim` so the
    /// resumed engine is faithful to the captured one. `estimator`
    /// supplies configuration (kind/α/dead band); its smoothing memory
    /// is overwritten with the checkpointed state. Epoch reports
    /// renumber from 0, but the cumulative counters (ticks, transfers,
    /// migration charge, and the epoch counter used for checkpoint
    /// filenames) continue from the snapshot, so
    /// [`DynamicReport::total_time`] stays the whole-run figure and a
    /// resumed run writing into the same `checkpoint_dir` continues
    /// the `epoch-NNNN.snap` sequence instead of overwriting it.
    pub fn from_snapshot(
        graph: &'g Graph,
        snap: &Snapshot,
        mut estimator: WeightEstimator,
        mut options: DynamicOptions,
    ) -> Self {
        assert_eq!(
            graph.node_count(),
            snap.node_weights.len(),
            "graph does not match the snapshot topology"
        );
        options.sim = snap.options.clone();
        let machines = snap.machines();
        estimator.import_state(snap.estimator.clone());
        let engine =
            SimEngine::from_state(graph, machines.clone(), options.sim.clone(), snap.engine.clone());
        DynamicDriver {
            graph,
            engine,
            lp_graph: snap.build_graph(),
            machines,
            estimator,
            options,
            epochs: Vec::new(),
            epoch_base: snap.epoch,
            recovery_ordinal: 0,
            admission_ordinal: 0,
            refinements: snap.refinements as usize,
            transfers: snap.transfers as usize,
            migration_ticks: snap.migration_ticks,
            cluster: None,
            last_checkpoint: Some(snap.encode()),
        }
    }

    /// Route every distributed refinement over a connected TCP cluster
    /// (broadcasts the shared fixture to the workers first). Requires
    /// `options.backend == RefineBackend::Distributed`.
    pub fn attach_cluster(&mut self, mut cluster: ClusterLeader) -> Result<(), WireError> {
        assert_eq!(
            self.options.backend,
            RefineBackend::Distributed,
            "a TCP cluster needs the distributed backend"
        );
        if let Some(layout) = &self.options.racks {
            if let Err(e) = cluster.set_racks(layout.clone()) {
                let _ = cluster.shutdown();
                return Err(e);
            }
        }
        if let Err(e) = cluster.setup(&self.lp_graph, &self.machines) {
            // Best-effort Goodbye so workers that did complete the
            // handshake exit now instead of waiting out their derived
            // epoch-wait timeout.
            let _ = cluster.shutdown();
            return Err(e);
        }
        self.cluster = Some(cluster);
        Ok(())
    }

    pub fn engine(&self) -> &SimEngine<'g> {
        &self.engine
    }

    /// The current fleet — shrinks when a recovery evicts dead
    /// machines and grows when a boundary admission re-adds one, so
    /// report consumers must read it from here rather than keep the
    /// pre-run config.
    pub fn machines(&self) -> &MachineConfig {
        &self.machines
    }

    /// The game-side graph carrying the latest measured/estimated LP
    /// weights — the basis the final partition was refined on, and
    /// therefore the right weighting for costing it.
    pub fn weighted_graph(&self) -> &Graph {
        &self.lp_graph
    }

    pub fn epochs(&self) -> &[EpochReport] {
        &self.epochs
    }

    /// Capture the full resumable state of the run: engine, game-side
    /// weighted graph, fleet, estimator memory, and the driver's
    /// cumulative counters (DESIGN.md §10). Only valid between engine
    /// ticks (any tick boundary; the epoch boundary is where the
    /// driver takes its own checkpoints).
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            options: self.options.sim.clone(),
            node_weights: self.lp_graph.node_weights().to_vec(),
            edges: self.lp_graph.edges().collect(),
            speeds: self.machines.speeds().to_vec(),
            epoch: self.epoch_base + self.epochs.len() as u64,
            refinements: self.refinements as u64,
            transfers: self.transfers as u64,
            migration_ticks: self.migration_ticks,
            estimator: self.estimator.export_state(),
            // The epoch loop is RNG-free (injections are precompiled),
            // so there are no live streams to carry.
            rng_streams: Vec::new(),
            engine: self.engine.capture_state(),
        }
    }

    /// Encoded bytes of the last epoch-boundary checkpoint, if
    /// checkpointing is active (cluster attached or `checkpoint_dir`
    /// set).
    pub fn last_checkpoint(&self) -> Option<&[u8]> {
        self.last_checkpoint.as_deref()
    }

    /// Best-effort write of an encoded snapshot into `checkpoint_dir`
    /// (checkpointing must never kill a healthy run — failures are
    /// reported on stderr and the in-memory copy still stands).
    fn write_checkpoint_file(&self, name: &str, bytes: &[u8]) {
        if let Some(dir) = &self.options.checkpoint_dir {
            let path = dir.join(name);
            if let Err(e) = std::fs::create_dir_all(dir).and_then(|()| std::fs::write(&path, bytes))
            {
                eprintln!("gtip snapshot: failed to write {}: {e}", path.display());
            }
        }
    }

    /// Potential of `part` on the current (re-measured) LP graph, under
    /// the configured framework.
    fn potential_of(&self, part: &Partition) -> f64 {
        match self.options.framework {
            Framework::A => global_cost::c0(&self.lp_graph, &self.machines, part, self.options.mu),
            Framework::B => {
                global_cost::c0_tilde(&self.lp_graph, &self.machines, part, self.options.mu)
            }
        }
    }

    /// Measure → estimate → install → refine (warm start) → migrate.
    /// Only the TCP-cluster path can fail; on error the cluster is
    /// deliberately left attached so the caller can diagnose the dead
    /// peers and recover over the survivors.
    fn refine_once(&mut self, counters: &EpochCounters) -> Result<EpochRefinement, WireError> {
        let raw = weights::measure_epoch(&self.engine, counters);
        let estimated = self.estimator.estimate(&raw);
        weights::install(&mut self.lp_graph, &estimated);

        let mut part = self.engine.partition().clone();
        part.rebuild_aggregates(&self.lp_graph);
        let imbalance_before = part.imbalance(&self.machines);

        let (potential_before, potential_after, transfers, converged, overhead, refined) =
            match self.options.backend {
                RefineBackend::Sequential => match &self.options.racks {
                    None => {
                        let mut refine = RefineEngine::new(
                            &self.lp_graph,
                            &self.machines,
                            part,
                            self.options.mu,
                            self.options.framework,
                        )
                        .with_migration_charge(self.options.migration_charge);
                        let before = refine.potential();
                        let report = refine.run(&RefineOptions::default());
                        (
                            before,
                            report.final_potential,
                            report.transfers,
                            report.converged,
                            None,
                            refine.into_partition(),
                        )
                    }
                    Some(layout) => {
                        let (refined, report) = refine_hierarchical(
                            &self.lp_graph,
                            &self.machines,
                            part,
                            self.options.mu,
                            self.options.framework,
                            self.options.migration_charge,
                            layout,
                            &RefineOptions::default(),
                        );
                        (
                            report.potential_before,
                            report.potential_after,
                            report.transfers,
                            report.converged,
                            None,
                            refined,
                        )
                    }
                },
                RefineBackend::Distributed => {
                    let before = self.potential_of(&part);
                    let report = if self.cluster.is_some() {
                        let result = self
                            .cluster
                            .as_mut()
                            .expect("checked above")
                            .refine(&self.lp_graph, &self.machines, part);
                        match result {
                            Ok(report) => report,
                            // The cluster is left attached: the caller
                            // (`try_run_epoch`) first tries to recover
                            // from the last checkpoint, and tears it
                            // down only when recovery is impossible.
                            Err(e) => return Err(e),
                        }
                    } else {
                        let dist_opts = DistributedOptions {
                            mu: self.options.mu,
                            framework: self.options.framework,
                            migration_charge: self.options.migration_charge,
                            ..Default::default()
                        };
                        match &self.options.racks {
                            None => run_distributed(
                                Arc::new(self.lp_graph.clone()),
                                &self.machines,
                                part,
                                &dist_opts,
                            ),
                            Some(layout) => run_distributed_hierarchical(
                                Arc::new(self.lp_graph.clone()),
                                &self.machines,
                                part,
                                layout,
                                &dist_opts,
                            ),
                        }
                    };
                    let after = self.potential_of(&report.partition);
                    (
                        before,
                        after,
                        report.transfers,
                        report.converged,
                        Some(report.overhead),
                        report.partition,
                    )
                }
            };

        let imbalance_after = refined.imbalance(&self.machines);
        let charge = self.options.ticks_per_transfer * transfers as u64;
        self.refinements += 1;
        self.transfers += transfers;
        self.migration_ticks += charge;
        self.engine.set_partition(refined);
        Ok(EpochRefinement {
            potential_before,
            potential_after,
            transfers,
            migration_ticks: charge,
            migration_cost: self.options.migration_charge * transfers as f64,
            imbalance_before,
            imbalance_after,
            converged,
            overhead,
        })
    }

    /// Best-effort cluster teardown (Goodbye) so surviving workers
    /// exit immediately instead of waiting out their epoch timeout.
    fn teardown_cluster(&mut self) {
        if let Some(cluster) = self.cluster.take() {
            let _ = cluster.shutdown();
        }
    }

    /// A refinement over the TCP cluster failed: diagnose which
    /// workers died, restore the run from the last epoch-boundary
    /// checkpoint, shrink the fleet to the survivors (renormalizing
    /// their relative speeds), re-home the dead machines' LPs, and
    /// re-run this epoch's refinement at K−1 over the compacted
    /// cluster (DESIGN.md §10). Loops if another worker dies during
    /// the retry — each round shrinks the fleet, so it terminates.
    /// Tears the cluster down and propagates when recovery is
    /// impossible: no checkpoint, no peer actually dead (the failure
    /// was the leader's own), or the recovery handshake itself failed.
    fn recover_and_refine(
        &mut self,
        mut err: WireError,
    ) -> Result<(EpochRefinement, RecoveryRecord), WireError> {
        let mut record: Option<RecoveryRecord> = None;
        loop {
            let Some(bytes) = self.last_checkpoint.clone() else {
                self.teardown_cluster();
                return Err(err);
            };
            let dead = match self.cluster.as_mut() {
                Some(cluster) => match cluster.diagnose_dead() {
                    // Every peer answered: the failure was not a
                    // worker death, so there is nothing to recover
                    // from — propagate the original error.
                    Ok(dead) if dead.is_empty() => {
                        self.teardown_cluster();
                        return Err(err);
                    }
                    Ok(dead) => dead,
                    Err(e) => {
                        self.teardown_cluster();
                        return Err(e);
                    }
                },
                None => return Err(err),
            };
            let snap = match Snapshot::decode(&bytes) {
                Ok(s) => s,
                Err(e) => {
                    self.teardown_cluster();
                    return Err(WireError::Protocol(format!("checkpoint unreadable: {e}")));
                }
            };
            let machines_before = snap.machine_count();
            debug_assert!(
                !dead.contains(&0) && dead.iter().all(|&m| m < machines_before),
                "dead set {dead:?} out of range for {machines_before} machines"
            );
            // Survivors keep their relative speeds, renormalized.
            let mut speeds: Vec<f64> = snap
                .speeds
                .iter()
                .enumerate()
                .filter(|(m, _)| !dead.contains(m))
                .map(|(_, &s)| s)
                .collect();
            let total: f64 = speeds.iter().sum();
            for s in &mut speeds {
                *s /= total;
            }
            let machines_after = MachineConfig::from_normalized(speeds);
            // Commit the survivors on the wire first (compact the
            // endpoint, broadcast Restore, await every ack) so local
            // state is only rebuilt once the cluster agreed.
            if let Err(e) =
                self.cluster.as_mut().expect("checked above").recover(&dead, &machines_after)
            {
                self.teardown_cluster();
                return Err(e);
            }
            // Restore game-side state from the checkpoint, re-home
            // the orphaned LPs, and rebuild the engine at K−1.
            self.lp_graph = snap.build_graph();
            self.estimator.import_state(snap.estimator.clone());
            self.refinements = snap.refinements as usize;
            self.transfers = snap.transfers as usize;
            self.migration_ticks = snap.migration_ticks;
            let (assignment, rehomed) =
                rehome_assignment(&snap.engine.assignment, &dead, &self.lp_graph, &machines_after);
            let mut state = snap.engine;
            state.assignment = assignment;
            self.engine = SimEngine::from_state(
                self.graph,
                machines_after.clone(),
                self.options.sim.clone(),
                state,
            );
            self.machines = machines_after;
            match &mut record {
                None => {
                    record = Some(RecoveryRecord {
                        dead_machines: dead.clone(),
                        machines_before,
                        machines_after: self.machines.count(),
                        rehomed_lps: rehomed,
                    })
                }
                Some(r) => {
                    r.dead_machines.extend(dead.iter().copied());
                    r.machines_after = self.machines.count();
                    r.rehomed_lps += rehomed;
                }
            }
            // Re-harvest the window the checkpoint preserved and
            // retry the refinement over the compacted cluster.
            // Checkpoint the restored K−1 state first: if *another*
            // worker dies during the retry, the next round must
            // restore in the new machine numbering.
            let counters = self.engine.take_epoch_counters();
            self.last_checkpoint = Some(self.snapshot().encode());
            match self.refine_once(&counters) {
                Ok(refinement) => {
                    // The post-refinement state is the new epoch
                    // boundary: `gtip dynamic --restore` on this file
                    // continues from here and (deterministically)
                    // reaches the same final state as this run. Named
                    // by recovery ordinal so a second recovery in the
                    // same run keeps the first's replay point intact.
                    let recovered = self.snapshot();
                    let encoded = recovered.encode();
                    self.write_checkpoint_file(
                        &format!("recovery-{:04}.snap", self.recovery_ordinal),
                        &encoded,
                    );
                    self.recovery_ordinal += 1;
                    self.last_checkpoint = Some(encoded);
                    return Ok((refinement, record.expect("at least one recovery round")));
                }
                Err(e) => err = e,
            }
        }
    }

    /// At an epoch boundary, admit one queued joiner if the attached
    /// cluster has one waiting — the grow half of elastic membership
    /// (DESIGN.md §10). Admission happens *only* here, never
    /// mid-epoch: the boundary is where a consistent state exists,
    /// and that state (remapped into the K+1 numbering) is exactly
    /// what the joiner receives as its `Catchup` payload. The joiner
    /// starts with zero LPs; the next refinement migrates load toward
    /// it under Thm 4.1's any-feasible-start descent, so no dedicated
    /// rebalancing pass is needed. A failed admission that rolled
    /// back cleanly returns `Ok(None)` and the run continues at K;
    /// `Err` means the rollback itself failed and the cluster was
    /// torn down.
    fn try_admit_pending(&mut self) -> Result<Option<AdmissionRecord>, WireError> {
        let Some(cluster) = self.cluster.as_mut() else {
            return Ok(None);
        };
        let Some(req) = cluster.pending_join() else {
            return Ok(None);
        };
        let joined_wire = req.wire_id;
        let speed = req.speed;
        let machines_before = self.machines.clone();
        let k_old = machines_before.count();
        // Wire ids stay ascending in the logical numbering, so the
        // joiner lands at this slot and every member to its right
        // shifts up by one.
        let pos = cluster.joiner_position(joined_wire);
        // The joiner's self-reported speed is relative to an average
        // machine of the original fleet; the survivors' normalized
        // speeds sum to 1, so an average-sized share next to them is
        // speed/K. `from_speeds` renormalizes the grown vector.
        let mut weights: Vec<f64> = machines_before.speeds().to_vec();
        weights.insert(pos, speed / k_old as f64);
        let machines_after = MachineConfig::from_speeds(&weights);
        // Build the K+1 boundary snapshot the joiner catches up from:
        // the current engine state with every assignment at or right
        // of the insertion slot shifted into the grown numbering.
        let mut state = self.engine.capture_state();
        for a in &mut state.assignment {
            if *a >= pos {
                *a += 1;
            }
        }
        let snap = Snapshot {
            options: self.options.sim.clone(),
            node_weights: self.lp_graph.node_weights().to_vec(),
            edges: self.lp_graph.edges().collect(),
            speeds: machines_after.speeds().to_vec(),
            epoch: self.epoch_base + self.epochs.len() as u64,
            refinements: self.refinements as u64,
            transfers: self.transfers as u64,
            migration_ticks: self.migration_ticks,
            estimator: self.estimator.export_state(),
            rng_streams: Vec::new(),
            engine: state.clone(),
        };
        let encoded = snap.encode();
        let admitted =
            cluster.admit(req, &self.lp_graph, &machines_before, &machines_after, &encoded);
        match admitted {
            Ok(false) => Ok(None),
            Ok(true) => {
                // The cluster agreed on the wire; rebuild local state
                // at K+1 to match what the joiner received.
                self.engine = SimEngine::from_state(
                    self.graph,
                    machines_after.clone(),
                    self.options.sim.clone(),
                    state,
                );
                self.machines = machines_after;
                self.write_checkpoint_file(
                    &format!("admit-{:04}.snap", self.admission_ordinal),
                    &encoded,
                );
                self.admission_ordinal += 1;
                self.last_checkpoint = Some(encoded);
                eprintln!(
                    "gtip leader: admitted wire id {joined_wire} as machine {pos} \
                     ({k_old} -> {} machines)",
                    self.machines.count()
                );
                Ok(Some(AdmissionRecord {
                    joined_wire_id: joined_wire,
                    joined_machine: pos,
                    machines_before: k_old,
                    machines_after: self.machines.count(),
                    speed,
                }))
            }
            Err(e) => {
                self.teardown_cluster();
                Err(e)
            }
        }
    }

    /// Run one epoch: up to `epoch_ticks` of simulation, then (if work
    /// remains and rebalancing is enabled) one refinement pass. Returns
    /// `Ok(false)` once the workload drained or the tick cap was hit.
    /// Only a TCP-cluster refinement can return `Err`; without an
    /// attached cluster this is infallible.
    pub fn try_run_epoch(&mut self) -> Result<bool, WireError> {
        if self.engine.drained() || self.engine.stats().ticks >= self.options.sim.max_ticks {
            return Ok(false);
        }
        let tick_start = self.engine.stats().ticks;
        // Wall clock = engine clock + every migration stall so far; the
        // per-epoch wall windows must tile [0, total_time()] exactly.
        let wall_tick_start = tick_start + self.migration_ticks;
        let budget = if self.options.epoch_ticks == 0 {
            self.options.sim.max_ticks
        } else {
            self.options.epoch_ticks
        };
        // Epoch boundary in absolute ticks; `step_bounded` keeps
        // fast-forward jumps inside it so epoch windows are exact.
        let limit = tick_start.saturating_add(budget).min(self.options.sim.max_ticks);
        while self.engine.stats().ticks < limit && self.engine.step_bounded(limit) {}
        // Grow the fleet first if a joiner is queued: the admission
        // must see the boundary state *before* the regular checkpoint
        // is taken, so the checkpoint (and any recovery later in this
        // epoch) already carries the K+1 fleet the cluster agreed on.
        let admission = self.try_admit_pending()?;
        // Epoch-boundary checkpoint — taken after the sim window but
        // *before* the window counters are harvested, so the snapshot
        // still holds the measurements and a restore can re-run the
        // refinement that consumes them (DESIGN.md §10). Named by the
        // cumulative epoch counter: a restored run renumbers epoch
        // *reports* from 0, but its files must continue the original
        // run's sequence, not overwrite it.
        if self.cluster.is_some() || self.options.checkpoint_dir.is_some() {
            let bytes = self.snapshot().encode();
            self.write_checkpoint_file(
                &format!("epoch-{:04}.snap", self.epoch_base + self.epochs.len() as u64),
                &bytes,
            );
            self.last_checkpoint = Some(bytes);
        }
        let counters = self.engine.take_epoch_counters();
        let tick_end = self.engine.stats().ticks;
        let more = !self.engine.drained() && tick_end < self.options.sim.max_ticks;

        let mut recovery = None;
        let refine = if more
            && self.options.epoch_ticks > 0
            && (self.options.max_refinements == 0 || self.refinements < self.options.max_refinements)
        {
            match self.refine_once(&counters) {
                Ok(refinement) => Some(refinement),
                // A worker died mid-refinement: restore from the
                // checkpoint just taken and finish the epoch with the
                // survivors instead of unwinding the whole round.
                Err(e) => {
                    let (refinement, rec) = self.recover_and_refine(e)?;
                    recovery = Some(rec);
                    Some(refinement)
                }
            }
        } else {
            None
        };

        // The refinement that closed this epoch stalls the run for its
        // migration charge, so the epoch's wall window (and therefore
        // its measured throughput) includes the stall — consistent with
        // `total_time()`, which bills the same ticks.
        let migration_ticks = refine.as_ref().map_or(0, |r| r.migration_ticks);
        let wall_tick_end = tick_end + self.migration_ticks;
        debug_assert_eq!(
            wall_tick_end - wall_tick_start,
            (tick_end - tick_start) + migration_ticks,
            "wall window must be the sim window plus this epoch's stall"
        );
        let window = (wall_tick_end - wall_tick_start).max(1);
        self.epochs.push(EpochReport {
            epoch: self.epochs.len(),
            tick_start,
            tick_end,
            wall_tick_start,
            wall_tick_end,
            migration_ticks,
            events_processed: counters.events_total(),
            rollbacks: counters.rollbacks_total(),
            cross_machine_forwards: counters.cross_forwards_total(),
            throughput: counters.events_total() as f64 / window as f64,
            refine,
            recovery,
            admission,
            racks: self.options.racks.as_ref().map_or(0, |l| l.rack_count()),
        });
        Ok(more)
    }

    /// Infallible [`DynamicDriver::try_run_epoch`]; panics on a TCP
    /// cluster failure (which cannot happen without an attached
    /// cluster — every in-process backend is infallible).
    pub fn run_epoch(&mut self) -> bool {
        self.try_run_epoch().unwrap_or_else(|e| panic!("distributed refinement failed: {e}"))
    }

    /// Run epochs until the workload drains (or `max_ticks`). Only a
    /// TCP-cluster refinement can return `Err` (after the cluster was
    /// torn down with a Goodbye so workers exit promptly).
    pub fn try_run(&mut self) -> Result<DynamicReport, WireError> {
        while self.try_run_epoch()? {}
        if let Some(cluster) = self.cluster.take() {
            // Graceful cluster teardown: workers exit on Goodbye.
            if let Err(e) = cluster.shutdown() {
                eprintln!("gtip net: cluster shutdown failed: {e}");
            }
        }
        let mut stats = self.engine.stats().clone();
        if !self.engine.drained() {
            stats.truncated = true;
        }
        Ok(DynamicReport {
            stats,
            epochs: self.epochs.clone(),
            transfers: self.transfers,
            migration_ticks: self.migration_ticks,
            load_traces: self.engine.load_traces().to_vec(),
        })
    }

    /// Infallible [`DynamicDriver::try_run`] for the in-process
    /// backends (panics on a TCP cluster failure).
    pub fn run(&mut self) -> DynamicReport {
        self.try_run().unwrap_or_else(|e| panic!("distributed refinement failed: {e}"))
    }
}

/// Run a full closed loop from an App.-A hop-growth initial partition
/// (unit weights) — the `gtip dynamic` entry point.
pub fn run_closed_loop(
    graph: &Graph,
    machines: &MachineConfig,
    injections: Vec<Injection>,
    estimator: WeightEstimator,
    options: &DynamicOptions,
    rng: &mut Pcg32,
) -> DynamicReport {
    let initial = grow_partition(graph, machines, rng);
    let mut driver = DynamicDriver::new(
        graph,
        machines.clone(),
        initial,
        injections,
        estimator,
        options.clone(),
    );
    driver.run()
}

/// Frozen-vs-rebalanced comparison on an identical graph, workload and
/// initial partition — the headline §6.1 experiment.
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub frozen: DynamicReport,
    pub rebalanced: DynamicReport,
}

impl CompareReport {
    /// `frozen time / rebalanced time` (> 1 means rebalancing won).
    /// Both arms draining in zero ticks (an empty workload) is a tie:
    /// 1.0, not the 0.0 the naive `0 / max(1)` would report — and the
    /// denominator clamp can only engage in that same degenerate case,
    /// so it never silently skews a real comparison.
    pub fn speedup(&self) -> f64 {
        CompareReport::speedup_of(self.frozen.total_time(), self.rebalanced.total_time())
    }

    /// The speedup definition on bare totals — for callers (e.g. the
    /// churn sweep) that hold one frozen run against many rebalanced
    /// arms without assembling a `CompareReport` per pair.
    pub fn speedup_of(frozen_time: u64, rebalanced_time: u64) -> f64 {
        if frozen_time == 0 && rebalanced_time == 0 {
            return 1.0;
        }
        frozen_time as f64 / rebalanced_time.max(1) as f64
    }
}

/// Run both arms. The frozen arm keeps `initial` for the whole run; the
/// rebalanced arm closes the loop with `estimator` every `epoch_ticks`.
pub fn compare_frozen_vs_rebalanced(
    graph: &Graph,
    machines: &MachineConfig,
    initial: &Partition,
    injections: &[Injection],
    estimator: WeightEstimator,
    options: &DynamicOptions,
) -> CompareReport {
    let frozen_options = DynamicOptions { epoch_ticks: 0, ..options.clone() };
    let frozen = DynamicDriver::new(
        graph,
        machines.clone(),
        initial.clone(),
        injections.to_vec(),
        WeightEstimator::instantaneous(),
        frozen_options,
    )
    .run_owned();
    let rebalanced = DynamicDriver::new(
        graph,
        machines.clone(),
        initial.clone(),
        injections.to_vec(),
        estimator,
        options.clone(),
    )
    .run_owned();
    CompareReport { frozen, rebalanced }
}

impl<'g> DynamicDriver<'g> {
    /// `run()` for by-value call chains.
    pub fn run_owned(mut self) -> DynamicReport {
        self.run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::preferential_attachment;
    use crate::sim::scenario::{Scenario, ScenarioKind, ScenarioOptions};

    fn setup(seed: u64) -> (Graph, MachineConfig, Scenario) {
        let mut rng = Pcg32::new(seed);
        let g = preferential_attachment(120, 2, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let scenario = Scenario::build(
            ScenarioKind::HotspotShift,
            &g,
            &ScenarioOptions { threads: 60, horizon_ticks: 900, ..Default::default() },
            &mut rng,
        );
        (g, machines, scenario)
    }

    fn options(epoch_ticks: u64) -> DynamicOptions {
        DynamicOptions {
            sim: SimOptions { max_ticks: 200_000, ..Default::default() },
            epoch_ticks,
            ..Default::default()
        }
    }

    #[test]
    fn closed_loop_runs_refines_and_reports() {
        let (g, machines, scenario) = setup(1);
        let mut rng = Pcg32::new(2);
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &options(150),
            &mut rng,
        );
        assert!(!report.stats.truncated, "truncated: {:?}", report.stats);
        assert!(report.refinements() > 0, "no refinement epochs ran");
        assert_eq!(report.epochs.last().map(|e| e.tick_end), Some(report.stats.ticks));
        // Every refinement descends its potential (Thm 4.1).
        for e in &report.epochs {
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after <= r.potential_before + 1e-9,
                    "epoch {}: potential rose {} -> {}",
                    e.epoch,
                    r.potential_before,
                    r.potential_after
                );
                assert!(r.converged);
            }
        }
        // Epoch windows tile the run.
        for pair in report.epochs.windows(2) {
            assert_eq!(pair[0].tick_end, pair[1].tick_start);
        }
    }

    /// Singleton racks in the closed loop reproduce the flat run
    /// exactly: with one machine per rack the outer game IS the flat
    /// game and the guarded map-back is the identity, so every epoch's
    /// refinement — and therefore the whole simulation trajectory —
    /// is bit-identical (DESIGN.md §12).
    #[test]
    fn singleton_racks_closed_loop_matches_flat_exactly() {
        let (g, machines, scenario) = setup(7);
        let flat = run_closed_loop(
            &g,
            &machines,
            scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            &options(150),
            &mut Pcg32::new(8),
        );
        let mut opts = options(150);
        opts.racks = Some(RackLayout::singletons(machines.count()));
        let hier = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut Pcg32::new(8),
        );
        assert_eq!(hier.stats, flat.stats);
        assert_eq!(hier.transfers, flat.transfers);
        assert_eq!(hier.epochs.len(), flat.epochs.len());
        for (h, f) in hier.epochs.iter().zip(flat.epochs.iter()) {
            assert_eq!(h.events_processed, f.events_processed);
            assert_eq!(h.rollbacks, f.rollbacks);
            match (&h.refine, &f.refine) {
                (Some(hr), Some(fr)) => {
                    assert_eq!(hr.transfers, fr.transfers);
                    // Same partition; the flat arm reports the engine's
                    // incrementally-maintained potential while the
                    // hierarchical arm recomputes it fresh, so compare
                    // to rounding, not bits.
                    let tol = 1e-9 * (1.0 + fr.potential_after.abs());
                    assert!(
                        (hr.potential_after - fr.potential_after).abs() <= tol,
                        "epoch {}: potential {} vs {}",
                        h.epoch,
                        hr.potential_after,
                        fr.potential_after
                    );
                }
                (None, None) => {}
                other => panic!("epoch {} refine mismatch: {other:?}", h.epoch),
            }
        }
        assert_eq!(hier.epochs[0].racks, machines.count());
        assert_eq!(flat.epochs[0].racks, 0);
    }

    /// Real (non-singleton) racks: every epoch's two-level refinement
    /// still descends the flat potential (outer guarded map-back +
    /// Thm 4.1 on each scoped inner game), and the epoch reports carry
    /// the rack count.
    #[test]
    fn hierarchical_closed_loop_descends_every_epoch() {
        let (g, machines, scenario) = setup(9);
        let mut opts = options(150);
        opts.racks = Some(RackLayout::new(vec![0, 0, 1, 1]).unwrap());
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut Pcg32::new(10),
        );
        assert!(!report.stats.truncated);
        assert!(report.refinements() > 0, "no refinement epochs ran");
        for e in &report.epochs {
            assert_eq!(e.racks, 2);
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after <= r.potential_before + 1e-9,
                    "epoch {}: flat potential rose {} -> {}",
                    e.epoch,
                    r.potential_before,
                    r.potential_after
                );
            }
        }
    }

    #[test]
    fn frozen_mode_never_refines() {
        let (g, machines, scenario) = setup(3);
        let mut rng = Pcg32::new(4);
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &options(0),
            &mut rng,
        );
        assert_eq!(report.refinements(), 0);
        assert_eq!(report.transfers, 0);
        assert!(!report.stats.truncated);
        assert_eq!(report.epochs.len(), 1, "frozen run is one long epoch");
    }

    #[test]
    fn migration_charges_accumulate() {
        let (g, machines, scenario) = setup(5);
        let mut rng = Pcg32::new(6);
        let mut opts = options(150);
        opts.ticks_per_transfer = 3;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert_eq!(report.migration_ticks, 3 * report.transfers as u64);
        assert_eq!(report.total_time(), report.stats.ticks + report.migration_ticks);
        let per_epoch: u64 =
            report.epochs.iter().filter_map(|e| e.refine.as_ref()).map(|r| r.migration_ticks).sum();
        assert_eq!(per_epoch, report.migration_ticks);
    }

    /// The migration-time accounting seam (regression): epoch *wall*
    /// windows must tile `[0, total_time()]` exactly — each window is
    /// the sim window plus that epoch's migration stall — and
    /// throughput must divide by the stalled window, so per-epoch
    /// metrics and the headline metric bill migration identically.
    #[test]
    fn wall_windows_tile_total_time_and_throughput_bills_migration() {
        let (g, machines, scenario) = setup(11);
        let mut rng = Pcg32::new(12);
        let mut opts = options(150);
        opts.ticks_per_transfer = 4;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(report.migration_ticks > 0, "fixture produced no migration charge");
        assert_eq!(report.epochs.first().map(|e| e.wall_tick_start), Some(0));
        for pair in report.epochs.windows(2) {
            assert_eq!(pair[0].wall_tick_end, pair[1].wall_tick_start, "wall windows must tile");
            assert_eq!(pair[0].tick_end, pair[1].tick_start, "sim windows must tile");
        }
        assert_eq!(
            report.epochs.last().map(|e| e.wall_tick_end),
            Some(report.total_time()),
            "wall clock must end at the headline total"
        );
        for e in &report.epochs {
            assert_eq!(
                e.wall_tick_end - e.wall_tick_start,
                (e.tick_end - e.tick_start) + e.migration_ticks,
                "epoch {}: wall window != sim window + stall",
                e.epoch
            );
            assert_eq!(e.migration_ticks, e.refine.as_ref().map_or(0, |r| r.migration_ticks));
            let wall_window = (e.wall_tick_end - e.wall_tick_start).max(1);
            assert_eq!(
                e.throughput.to_bits(),
                (e.events_processed as f64 / wall_window as f64).to_bits(),
                "epoch {}: throughput must divide by the stalled window",
                e.epoch
            );
        }
        // total_time, windows, and throughput pinned together.
        let summed: u64 = report
            .epochs
            .iter()
            .map(|e| e.wall_tick_end - e.wall_tick_start)
            .sum();
        assert_eq!(summed, report.total_time());
    }

    /// `CompareReport::speedup` on the degenerate empty workload (both
    /// arms drain in zero ticks) is defined as 1.0, not 0.0.
    #[test]
    fn speedup_of_empty_workload_is_one() {
        let (g, machines, _) = setup(13);
        let mut rng = Pcg32::new(14);
        let initial = grow_partition(&g, &machines, &mut rng);
        let report = compare_frozen_vs_rebalanced(
            &g,
            &machines,
            &initial,
            &[], // no injections: both arms drain instantly
            WeightEstimator::instantaneous(),
            &options(150),
        );
        assert_eq!(report.frozen.total_time(), 0);
        assert_eq!(report.rebalanced.total_time(), 0);
        assert_eq!(report.speedup(), 1.0);
        // The bare-totals helper agrees with the method everywhere.
        assert_eq!(CompareReport::speedup_of(0, 0), 1.0);
        assert_eq!(CompareReport::speedup_of(100, 50), 2.0);
        assert_eq!(CompareReport::speedup_of(7, 0), 7.0);
    }

    /// The in-game charge prices moves inside the closed loop: every
    /// refinement epoch satisfies the augmented-descent guarantee
    /// `potential_after + migration_cost <= potential_before`, the
    /// per-epoch churn bound `transfers <= ΔΦ / (2·c_mig)` (framework A
    /// default), and `migration_cost` bills exactly charge × transfers.
    /// (The prohibitive-charge freeze and the free-vs-charged triple
    /// are covered end-to-end by
    /// `integration_dynamic::in_game_charge_reduces_churn_end_to_end`.)
    #[test]
    fn in_game_charge_damps_closed_loop_churn() {
        let (g, machines, scenario) = setup(15);
        let mut rng = Pcg32::new(16);
        let mut opts = options(150);
        opts.migration_charge = 50.0;
        let charged = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(charged.refinements() > 0, "loop never refined; test is vacuous");
        for e in &charged.epochs {
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after + r.migration_cost
                        <= r.potential_before + 1e-9 * (1.0 + r.potential_before.abs()),
                    "epoch {}: augmented descent violated: {} + {} > {}",
                    e.epoch,
                    r.potential_after,
                    r.migration_cost,
                    r.potential_before
                );
                assert_eq!(r.migration_cost, 50.0 * r.transfers as f64);
                // Churn bound theorem: each move drops the raw
                // potential by >= 2*c_mig under framework A.
                assert!(
                    r.transfers as f64
                        <= (r.potential_before - r.potential_after) / (2.0 * 50.0)
                            * (1.0 + 1e-9)
                            + 1e-9,
                    "epoch {}: churn bound violated",
                    e.epoch
                );
            }
        }
    }

    #[test]
    fn max_refinements_caps_the_loop() {
        let (g, machines, scenario) = setup(7);
        let mut rng = Pcg32::new(8);
        let mut opts = options(100);
        opts.max_refinements = 2;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(report.refinements() <= 2);
        assert!(!report.stats.truncated);
    }

    #[test]
    fn distributed_backend_matches_sequential_loop() {
        let (g, machines, scenario) = setup(9);
        let mut opts = options(200);
        let mut rng = Pcg32::new(10);
        let initial = grow_partition(&g, &machines, &mut rng);

        let seq = DynamicDriver::new(
            &g,
            machines.clone(),
            initial.clone(),
            scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            opts.clone(),
        )
        .run_owned();

        opts.backend = RefineBackend::Distributed;
        let dist = DynamicDriver::new(
            &g,
            machines.clone(),
            initial,
            scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            opts,
        )
        .run_owned();

        // Same deterministic turn order => the whole closed loop agrees.
        assert_eq!(seq.stats.ticks, dist.stats.ticks);
        assert_eq!(seq.transfers, dist.transfers);
        assert_eq!(seq.epochs.len(), dist.epochs.len());
        // Only the message-passing backend accumulates sync overhead.
        assert!(seq.total_overhead().is_none());
        let overhead = dist.total_overhead().expect("distributed epochs measure overhead");
        assert!(overhead.total_messages() > 0);
        for (a, b) in seq.epochs.iter().zip(&dist.epochs) {
            match (&a.refine, &b.refine) {
                (Some(ra), Some(rb)) => {
                    assert_eq!(ra.transfers, rb.transfers);
                    assert!((ra.potential_after - rb.potential_after).abs() < 1e-6);
                }
                (None, None) => {}
                other => panic!("refinement schedule diverged: {other:?}"),
            }
        }
    }

    #[test]
    fn ewma_smooths_toward_new_signal() {
        let raw1 = MeasuredWeights {
            node_weights: vec![10.0, 0.0],
            edge_weights: vec![(0, 1, 4.0)],
        };
        let raw2 = MeasuredWeights {
            node_weights: vec![0.0, 10.0],
            edge_weights: vec![(0, 1, 0.0)],
        };
        let mut est = WeightEstimator::ewma(0.5);
        let first = est.estimate(&raw1);
        assert_eq!(first.node_weights, vec![10.0, 0.0], "first call primes");
        let second = est.estimate(&raw2);
        // Halfway between the two signals.
        assert!((second.node_weights[0] - 5.0).abs() < 1e-12);
        assert!((second.node_weights[1] - 5.0).abs() < 1e-12);
        assert!((second.edge_weights[0].2 - 2.0).abs() < 1e-12);
        // Repeated exposure converges to the new signal.
        for _ in 0..20 {
            est.estimate(&raw2);
        }
        let converged = est.estimate(&raw2);
        assert!((converged.node_weights[1] - 10.0).abs() < 1e-2);
    }

    #[test]
    fn hysteresis_holds_output_inside_deadband() {
        let raw = MeasuredWeights {
            node_weights: vec![10.0],
            edge_weights: vec![(0, 1, 10.0)],
        };
        let wiggle = MeasuredWeights {
            node_weights: vec![10.5],
            edge_weights: vec![(0, 1, 10.5)],
        };
        let jump = MeasuredWeights {
            node_weights: vec![30.0],
            edge_weights: vec![(0, 1, 30.0)],
        };
        let mut est = WeightEstimator::hysteresis(1.0, 0.25);
        let a = est.estimate(&raw);
        assert_eq!(a.node_weights[0], 10.0);
        // 5% wiggle stays inside the 25% dead band: output frozen.
        let b = est.estimate(&wiggle);
        assert_eq!(b.node_weights[0], 10.0);
        assert_eq!(b.edge_weights[0].2, 10.0);
        // A 3x jump breaks out.
        let c = est.estimate(&jump);
        assert_eq!(c.node_weights[0], 30.0);
        assert_eq!(c.edge_weights[0].2, 30.0);
    }

    #[test]
    fn charge_transfers_derives_the_in_game_price() {
        let opts = DynamicOptions::default().charge_transfers(3, 2.5);
        assert_eq!(opts.ticks_per_transfer, 3);
        assert_eq!(opts.migration_charge, 7.5);
        let free = DynamicOptions::default().charge_transfers(5, 0.0);
        assert_eq!(free.ticks_per_transfer, 5);
        assert_eq!(free.migration_charge, 0.0);
    }

    /// The driver-level checkpoint substrate: a snapshot taken at an
    /// epoch boundary re-encodes byte-identically through a decode,
    /// and a driver resumed from it finishes the run with exactly the
    /// same cumulative stats as the uninterrupted original.
    #[test]
    fn driver_snapshot_restores_and_continues_identically() {
        let (g, machines, scenario) = setup(21);
        let mut rng = Pcg32::new(22);
        let initial = grow_partition(&g, &machines, &mut rng);
        let opts = options(150);
        let mut live = DynamicDriver::new(
            &g,
            machines.clone(),
            initial,
            scenario.injections.clone(),
            WeightEstimator::ewma(0.5),
            opts.clone(),
        );
        assert!(live.try_run_epoch().unwrap(), "fixture drained before the checkpoint");
        assert!(live.try_run_epoch().unwrap(), "fixture drained before the checkpoint");

        let snap = live.snapshot();
        let bytes = snap.encode();
        let decoded = Snapshot::decode(&bytes).expect("decode");
        assert_eq!(bytes, decoded.encode(), "save -> load -> save must be byte-identical");
        assert!(decoded.estimator.is_some(), "two epochs must prime the EWMA");

        let g2 = decoded.build_graph();
        let mut restored =
            DynamicDriver::from_snapshot(&g2, &decoded, WeightEstimator::ewma(0.5), opts);
        let restored_report = restored.run();
        let live_report = live.run();
        assert_eq!(live_report.stats, restored_report.stats);
        assert_eq!(live_report.transfers, restored_report.transfers);
        assert_eq!(live_report.migration_ticks, restored_report.migration_ticks);
        assert_eq!(live_report.total_time(), restored_report.total_time());
        // The live run keeps its pre-checkpoint epoch reports; the
        // restored run renumbers from the checkpoint. The tails match.
        assert_eq!(live_report.epochs.len(), restored_report.epochs.len() + 2);
        for (a, b) in live_report.epochs[2..].iter().zip(&restored_report.epochs) {
            assert_eq!(a.tick_start, b.tick_start);
            assert_eq!(a.tick_end, b.tick_end);
            assert_eq!(a.events_processed, b.events_processed);
            assert_eq!(a.refine.is_some(), b.refine.is_some());
            if let (Some(ra), Some(rb)) = (&a.refine, &b.refine) {
                assert_eq!(ra.transfers, rb.transfers);
                assert_eq!(ra.potential_after.to_bits(), rb.potential_after.to_bits());
            }
        }
    }

    /// `checkpoint_dir` materializes one snapshot per epoch boundary,
    /// each readable and byte-stable through a decode/encode cycle.
    #[test]
    fn checkpoint_dir_writes_epoch_snapshots() {
        let dir = std::env::temp_dir().join(format!("gtip-ckpt-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (g, machines, scenario) = setup(23);
        let mut rng = Pcg32::new(24);
        let mut opts = options(150);
        opts.checkpoint_dir = Some(dir.clone());
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(report.refinements() > 0);
        let first = dir.join("epoch-0000.snap");
        let snap = Snapshot::read_from(&first).expect("first epoch checkpoint must exist");
        assert_eq!(snap.epoch, 0);
        assert_eq!(snap.machine_count(), machines.count());
        assert_eq!(snap.encode(), std::fs::read(&first).unwrap(), "file is canonical bytes");
        // One file per epoch boundary that was checkpointed.
        let count = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(count, report.epochs.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A run resumed from a snapshot into the *same* `checkpoint_dir`
    /// continues the `epoch-NNNN.snap` sequence from the cumulative
    /// epoch counter instead of renumbering from zero and silently
    /// overwriting the original run's files.
    #[test]
    fn restored_run_extends_checkpoint_sequence_without_overwriting() {
        let dir = std::env::temp_dir().join(format!("gtip-ckpt-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (g, machines, scenario) = setup(29);
        let mut rng = Pcg32::new(30);
        let initial = grow_partition(&g, &machines, &mut rng);
        let mut opts = options(150);
        opts.checkpoint_dir = Some(dir.clone());
        let mut live = DynamicDriver::new(
            &g,
            machines.clone(),
            initial,
            scenario.injections.clone(),
            WeightEstimator::ewma(0.5),
            opts.clone(),
        );
        assert!(live.try_run_epoch().unwrap(), "fixture drained before the checkpoint");
        assert!(live.try_run_epoch().unwrap(), "fixture drained before the checkpoint");
        let snap = live.snapshot();
        assert_eq!(snap.epoch, 2, "two boundaries passed");
        let originals: Vec<Vec<u8>> = (0..2)
            .map(|e| std::fs::read(dir.join(format!("epoch-{e:04}.snap"))).expect("original snap"))
            .collect();

        let g2 = snap.build_graph();
        let mut restored =
            DynamicDriver::from_snapshot(&g2, &snap, WeightEstimator::ewma(0.5), opts);
        let report = restored.run();
        assert!(!report.epochs.is_empty(), "the resumed run must do work");
        assert!(
            dir.join("epoch-0002.snap").exists(),
            "the resumed run's first boundary continues the cumulative sequence"
        );
        for (e, bytes) in originals.iter().enumerate() {
            assert_eq!(
                &std::fs::read(dir.join(format!("epoch-{e:04}.snap"))).unwrap(),
                bytes,
                "the original run's epoch-{e:04}.snap must survive the resumed run"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn estimator_and_backend_parse_from_strings() {
        assert_eq!("ewma".parse::<EstimatorKind>().unwrap(), EstimatorKind::Ewma);
        assert_eq!(
            "hysteresis".parse::<EstimatorKind>().unwrap(),
            EstimatorKind::Hysteresis
        );
        assert!("nope".parse::<EstimatorKind>().is_err());
        assert_eq!("sequential".parse::<RefineBackend>().unwrap(), RefineBackend::Sequential);
        assert_eq!("dist".parse::<RefineBackend>().unwrap(), RefineBackend::Distributed);
        assert!("p2p".parse::<RefineBackend>().is_err());
    }
}

//! Checkpoint capture and restore for the closed loop (DESIGN.md
//! §10): the epoch-boundary [`Snapshot`] a run writes (and recovery
//! restores from), plus the `gtip dynamic --restore` constructor that
//! resumes a driver from a decoded snapshot.

use crate::graph::Graph;
use crate::sim::engine::SimEngine;
use crate::sim::snapshot::Snapshot;

use super::driver::{DynamicDriver, DynamicOptions};
use super::WeightEstimator;

impl<'g> DynamicDriver<'g> {
    /// Resume a run from a decoded epoch-boundary [`Snapshot`] — the
    /// `gtip dynamic --restore` entry point. `graph` must have the
    /// snapshot's topology (use [`Snapshot::build_graph`]); the sim
    /// options stored in the snapshot override `options.sim` so the
    /// resumed engine is faithful to the captured one. `estimator`
    /// supplies configuration (kind/α/dead band); its smoothing memory
    /// is overwritten with the checkpointed state. Epoch reports
    /// renumber from 0, but the cumulative counters (ticks, transfers,
    /// migration charge, and the epoch counter used for checkpoint
    /// filenames) continue from the snapshot, so
    /// [`DynamicReport::total_time`] stays the whole-run figure and a
    /// resumed run writing into the same `checkpoint_dir` continues
    /// the `epoch-NNNN.snap` sequence instead of overwriting it.
    pub fn from_snapshot(
        graph: &'g Graph,
        snap: &Snapshot,
        mut estimator: WeightEstimator,
        mut options: DynamicOptions,
    ) -> Self {
        assert_eq!(
            graph.node_count(),
            snap.node_weights.len(),
            "graph does not match the snapshot topology"
        );
        options.sim = snap.options.clone();
        let machines = snap.machines();
        estimator.import_state(snap.estimator.clone());
        let engine =
            SimEngine::from_state(graph, machines.clone(), options.sim.clone(), snap.engine.clone());
        DynamicDriver {
            graph,
            engine,
            lp_graph: snap.build_graph(),
            machines,
            estimator,
            options,
            epochs: Vec::new(),
            epoch_base: snap.epoch,
            recovery_ordinal: 0,
            admission_ordinal: 0,
            refinements: snap.refinements as usize,
            transfers: snap.transfers as usize,
            migration_ticks: snap.migration_ticks,
            cluster: None,
            last_checkpoint: Some(snap.encode()),
        }
    }

    /// Capture the full resumable state of the run: engine, game-side
    /// weighted graph, fleet, estimator memory, and the driver's
    /// cumulative counters (DESIGN.md §10). Only valid between engine
    /// ticks (any tick boundary; the epoch boundary is where the
    /// driver takes its own checkpoints).
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            options: self.options.sim.clone(),
            node_weights: self.lp_graph.node_weights().to_vec(),
            edges: self.lp_graph.edges().collect(),
            speeds: self.machines.speeds().to_vec(),
            epoch: self.epoch_base + self.epochs.len() as u64,
            refinements: self.refinements as u64,
            transfers: self.transfers as u64,
            migration_ticks: self.migration_ticks,
            estimator: self.estimator.export_state(),
            // The epoch loop is RNG-free (injections are precompiled),
            // so there are no live streams to carry.
            rng_streams: Vec::new(),
            engine: self.engine.capture_state(),
        }
    }

    /// Encoded bytes of the last epoch-boundary checkpoint, if
    /// checkpointing is active (cluster attached or `checkpoint_dir`
    /// set).
    pub fn last_checkpoint(&self) -> Option<&[u8]> {
        self.last_checkpoint.as_deref()
    }

    /// Best-effort write of an encoded snapshot into `checkpoint_dir`
    /// (checkpointing must never kill a healthy run — failures are
    /// reported on stderr and the in-memory copy still stands).
    pub(super) fn write_checkpoint_file(&self, name: &str, bytes: &[u8]) {
        if let Some(dir) = &self.options.checkpoint_dir {
            let path = dir.join(name);
            if let Err(e) = std::fs::create_dir_all(dir).and_then(|()| std::fs::write(&path, bytes))
            {
                eprintln!("gtip snapshot: failed to write {}: {e}", path.display());
            }
        }
    }
}

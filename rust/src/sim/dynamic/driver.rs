//! The closed-loop core (DESIGN.md §13): [`DynamicDriver`] and its
//! epoch loop — simulate a window, harvest measured loads, estimate
//! weights, refine warm-started (sequential, hierarchical, in-process
//! distributed, or over an attached TCP cluster), migrate, report.
//! Membership changes live in [`super::membership`], checkpoint
//! capture/restore in [`super::checkpoint`].

use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::net::ClusterLeader;
use crate::coordinator::{
    run_distributed, run_distributed_hierarchical, DistributedOptions, OverheadStats, WireError,
};
use crate::game::cost::Framework;
use crate::game::hierarchy::{refine_hierarchical, RackLayout};
use crate::game::refine::{RefineEngine, RefineOptions};
use crate::graph::Graph;
use crate::partition::initial::grow_partition;
use crate::partition::{global_cost, MachineConfig, Partition};
use crate::sim::engine::{EpochCounters, Injection, SimEngine, SimOptions, SimStats};
use crate::sim::weights;
use crate::util::rng::Pcg32;
use crate::util::stats::Trace;
use crate::util::table::Table;

use super::membership::{AdmissionRecord, RecoveryRecord};
use super::WeightEstimator;

/// Which refinement implementation closes the loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefineBackend {
    /// In-process [`RefineEngine`] (fast path).
    Sequential,
    /// One-thread-per-machine actor protocol
    /// ([`run_distributed`]) — produces the identical equilibrium (same
    /// deterministic turn order) while measuring the O(K) sync traffic.
    Distributed,
}

impl std::str::FromStr for RefineBackend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "seq" | "sequential" => Ok(RefineBackend::Sequential),
            "dist" | "distributed" | "coordinator" => Ok(RefineBackend::Distributed),
            other => Err(format!(
                "unknown backend {other:?} (expected sequential|distributed)"
            )),
        }
    }
}

impl std::fmt::Display for RefineBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RefineBackend::Sequential => "sequential",
            RefineBackend::Distributed => "distributed",
        })
    }
}

/// Options of the closed loop.
#[derive(Debug, Clone)]
pub struct DynamicOptions {
    pub sim: SimOptions,
    /// Wall ticks per simulation epoch; 0 freezes the initial partition
    /// (the static baseline).
    pub epoch_ticks: u64,
    pub framework: Framework,
    /// Relative rollback-delay weight μ.
    pub mu: f64,
    pub backend: RefineBackend,
    /// Wall-tick charge per executed LP migration (the paper ignores
    /// migration cost; default 0).
    pub ticks_per_transfer: u64,
    /// Per-move surcharge `c_mig` priced *inside* the refinement game
    /// (augmented dissatisfaction, DESIGN.md §9): a transfer is only
    /// accepted when its raw cost gain exceeds this many cost units.
    /// Use [`DynamicOptions::charge_transfers`] to derive it from
    /// `ticks_per_transfer` so the game prices exactly what the report
    /// bills. 0 reproduces the paper's charge-free game.
    pub migration_charge: f64,
    /// Cap on refinement epochs (0 = unlimited).
    pub max_refinements: usize,
    /// When set, every epoch-boundary [`Snapshot`] is also written
    /// here (`epoch-NNNN.snap`, numbered by the *cumulative* epoch
    /// counter so a restored run never overwrites the original run's
    /// files; plus `recovery-NNNN.snap` after each worker death and
    /// `admit-NNNN.snap` after each admission), so an operator can
    /// inspect or `--restore` them. The in-memory checkpoint that
    /// powers live recovery is kept whenever a TCP cluster is
    /// attached, with or without this directory.
    pub checkpoint_dir: Option<PathBuf>,
    /// Two-level hierarchy (DESIGN.md §12): when set, every refinement
    /// epoch plays the outer rack-quotient game then the scoped inner
    /// per-rack games instead of the flat K-machine game. `None` (the
    /// default) keeps the flat game. The layout must cover exactly the
    /// starting fleet; singleton racks reproduce the flat equilibrium
    /// bit-for-bit.
    pub racks: Option<RackLayout>,
}

impl Default for DynamicOptions {
    fn default() -> Self {
        DynamicOptions {
            sim: SimOptions::default(),
            epoch_ticks: 200,
            framework: Framework::A,
            mu: 8.0,
            backend: RefineBackend::Sequential,
            ticks_per_transfer: 0,
            migration_charge: 0.0,
            max_refinements: 0,
            checkpoint_dir: None,
            racks: None,
        }
    }
}

impl DynamicOptions {
    /// Bill each transfer `ticks` wall ticks in the report AND price it
    /// at `c_mig = ticks · tick_value` cost units inside the game, so
    /// refinement only moves an LP when its modeled gain beats what the
    /// migration will cost the run. `tick_value` converts wall ticks to
    /// cost units (1.0 when node weights are events-per-window, the
    /// closed loop's default measurement).
    pub fn charge_transfers(mut self, ticks: u64, tick_value: f64) -> Self {
        assert!(tick_value >= 0.0 && tick_value.is_finite(), "tick value must be finite and >= 0");
        self.ticks_per_transfer = ticks;
        self.migration_charge = ticks as f64 * tick_value;
        self
    }
}

/// What one refinement epoch did.
#[derive(Debug, Clone)]
pub struct EpochRefinement {
    /// Potential on the re-measured weights *before* refining (warm
    /// start = current partition).
    pub potential_before: f64,
    /// Potential at the refined equilibrium. Never exceeds
    /// `potential_before` (Thm 4.1 descent).
    pub potential_after: f64,
    /// LP migrations executed.
    pub transfers: usize,
    /// Wall-tick migration charge of this epoch.
    pub migration_ticks: u64,
    /// In-game migration spend of this epoch: `c_mig · transfers`, in
    /// cost units. `potential_after + migration_cost ≤ potential_before`
    /// is the augmented-descent guarantee (DESIGN.md §9).
    pub migration_cost: f64,
    pub imbalance_before: f64,
    pub imbalance_after: f64,
    /// Whether refinement reached a Nash equilibrium (vs the cap).
    pub converged: bool,
    /// Measured coordinator sync traffic of this epoch (exact wire
    /// bytes) — `None` on the sequential backend, which sends nothing.
    pub overhead: Option<OverheadStats>,
}

/// Per-epoch record of the closed loop.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub epoch: usize,
    /// Simulation-tick window (engine clock; migration stalls excluded).
    pub tick_start: u64,
    pub tick_end: u64,
    /// Wall-clock window including migration stalls: `wall_tick_start`
    /// is `tick_start` plus every earlier epoch's migration charge, and
    /// `wall_tick_end` additionally includes *this* epoch's charge —
    /// epoch wall windows tile `[0, DynamicReport::total_time()]`
    /// exactly, so per-epoch weights and throughput bill migration time
    /// the same way the headline metric does.
    pub wall_tick_start: u64,
    pub wall_tick_end: u64,
    /// Wall-tick migration charge of this epoch's refinement (0 when
    /// the epoch did not refine).
    pub migration_ticks: u64,
    /// Events completed during the window.
    pub events_processed: u64,
    /// Rollback episodes during the window.
    pub rollbacks: u64,
    /// Cross-machine forwards during the window.
    pub cross_machine_forwards: u64,
    /// Events per *wall* tick over the window, migration stall
    /// included — the throughput the rebalancer tries to keep high.
    /// Before the accounting fix this divided by the simulation window
    /// only, so measured throughput pretended migration was free while
    /// `total_time()` charged it.
    pub throughput: f64,
    /// `None` on frozen (baseline) epochs and on the drain-out tail.
    pub refine: Option<EpochRefinement>,
    /// Set when one or more workers died during this epoch's
    /// refinement and the run restored from the last epoch-boundary
    /// checkpoint instead of unwinding (DESIGN.md §10).
    pub recovery: Option<RecoveryRecord>,
    /// Set when a queued joiner was admitted at this epoch's boundary
    /// and the fleet grew to K+1 before the epoch's refinement ran.
    pub admission: Option<AdmissionRecord>,
    /// Rack count of the hierarchy the refinement played (DESIGN.md
    /// §12); 0 when the epoch ran the flat game.
    pub racks: usize,
}

/// Aggregate result of a closed-loop run.
#[derive(Debug, Clone)]
pub struct DynamicReport {
    pub stats: SimStats,
    pub epochs: Vec<EpochReport>,
    /// Total LP migrations across all refinement epochs.
    pub transfers: usize,
    /// Total wall-tick migration charge.
    pub migration_ticks: u64,
    /// Machine-load traces (populated if `sim.trace_every > 0`).
    pub load_traces: Vec<Trace>,
}

impl DynamicReport {
    /// Total simulation time including migration charges — the paper's
    /// headline metric.
    pub fn total_time(&self) -> u64 {
        self.stats.ticks + self.migration_ticks
    }

    /// Number of refinement epochs that actually ran.
    pub fn refinements(&self) -> usize {
        self.epochs.iter().filter(|e| e.refine.is_some()).count()
    }

    /// Number of epochs that survived a worker death by restoring
    /// from the last checkpoint.
    pub fn recoveries(&self) -> usize {
        self.epochs.iter().filter(|e| e.recovery.is_some()).count()
    }

    /// Number of epochs that grew the fleet by admitting a joiner at
    /// their boundary.
    pub fn admissions(&self) -> usize {
        self.epochs.iter().filter(|e| e.admission.is_some()).count()
    }

    /// Refinement epochs whose potential *rose* — Thm 4.1 says this is
    /// impossible, so any non-zero count is a bug. `sim::fuzz` treats
    /// violations as first-class findings and the regression suite
    /// asserts the committed corpus keeps this at zero.
    pub fn descent_violations(&self) -> usize {
        self.epochs
            .iter()
            .filter_map(|e| e.refine.as_ref())
            .filter(|r| {
                r.potential_after > r.potential_before + 1e-9 * (1.0 + r.potential_before.abs())
            })
            .count()
    }

    /// Total coordinator sync traffic across every refinement epoch
    /// (`None` if no epoch used a message-passing backend).
    pub fn total_overhead(&self) -> Option<OverheadStats> {
        let mut total: Option<OverheadStats> = None;
        for r in self.epochs.iter().filter_map(|e| e.refine.as_ref()) {
            if let Some(o) = &r.overhead {
                total.get_or_insert_with(OverheadStats::default).add(o);
            }
        }
        total
    }

    /// Render the per-epoch stream as a table.
    pub fn epoch_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "epoch", "wall ticks", "mig", "events", "ev/tick", "rollbacks",
                "x-machine", "transfers", "potential",
            ],
        );
        for e in &self.epochs {
            let (transfers, potential) = match &e.refine {
                Some(r) => (
                    r.transfers.to_string(),
                    format!("{:.0} -> {:.0}", r.potential_before, r.potential_after),
                ),
                None => ("-".into(), "(frozen)".into()),
            };
            t.row(&[
                e.epoch.to_string(),
                format!("{}..{}", e.wall_tick_start, e.wall_tick_end),
                e.migration_ticks.to_string(),
                e.events_processed.to_string(),
                format!("{:.3}", e.throughput),
                e.rollbacks.to_string(),
                e.cross_machine_forwards.to_string(),
                transfers,
                potential,
            ]);
        }
        t
    }
}

/// The closed-loop driver. Borrows the (topology-)immutable LP graph;
/// owns a private weighted copy for the refinement side.
pub struct DynamicDriver<'g> {
    /// The immutable LP topology the engine borrows — kept so the
    /// engine can be *rebuilt* from a checkpoint during recovery.
    pub(super) graph: &'g Graph,
    pub(super) engine: SimEngine<'g>,
    pub(super) lp_graph: Graph,
    pub(super) machines: MachineConfig,
    pub(super) estimator: WeightEstimator,
    pub(super) options: DynamicOptions,
    pub(super) epochs: Vec<EpochReport>,
    /// Epochs completed *before* this driver existed (non-zero only
    /// when restored from a snapshot). Epoch reports renumber from 0
    /// per run, but checkpoint filenames and the `epoch` counter
    /// stored in snapshots use `epoch_base + epochs.len()`, so a
    /// resumed run sharing `checkpoint_dir` with the original never
    /// overwrites the original's files.
    pub(super) epoch_base: u64,
    /// Recoveries taken this run — names `recovery-NNNN.snap` so a
    /// second recovery does not overwrite the first's replay point.
    pub(super) recovery_ordinal: usize,
    /// Admissions granted this run — names `admit-NNNN.snap`.
    pub(super) admission_ordinal: usize,
    pub(super) refinements: usize,
    pub(super) transfers: usize,
    pub(super) migration_ticks: u64,
    /// When attached, the distributed backend refines over this real
    /// multi-process TCP cluster instead of in-process actor threads.
    pub(super) cluster: Option<ClusterLeader>,
    /// Encoded bytes of the last epoch-boundary [`Snapshot`] —
    /// restored from on worker death. Kept whenever a cluster is
    /// attached or `checkpoint_dir` is set.
    pub(super) last_checkpoint: Option<Vec<u8>>,
}

impl<'g> DynamicDriver<'g> {
    pub fn new(
        graph: &'g Graph,
        machines: MachineConfig,
        initial: Partition,
        injections: Vec<Injection>,
        estimator: WeightEstimator,
        options: DynamicOptions,
    ) -> Self {
        let engine =
            SimEngine::new(graph, machines.clone(), initial, options.sim.clone(), injections);
        DynamicDriver {
            graph,
            engine,
            lp_graph: graph.clone(),
            machines,
            estimator,
            options,
            epochs: Vec::new(),
            epoch_base: 0,
            recovery_ordinal: 0,
            admission_ordinal: 0,
            refinements: 0,
            transfers: 0,
            migration_ticks: 0,
            cluster: None,
            last_checkpoint: None,
        }
    }

    pub fn engine(&self) -> &SimEngine<'g> {
        &self.engine
    }

    /// The current fleet — shrinks when a recovery evicts dead
    /// machines and grows when a boundary admission re-adds one, so
    /// report consumers must read it from here rather than keep the
    /// pre-run config.
    pub fn machines(&self) -> &MachineConfig {
        &self.machines
    }

    /// The game-side graph carrying the latest measured/estimated LP
    /// weights — the basis the final partition was refined on, and
    /// therefore the right weighting for costing it.
    pub fn weighted_graph(&self) -> &Graph {
        &self.lp_graph
    }

    pub fn epochs(&self) -> &[EpochReport] {
        &self.epochs
    }

    /// Potential of `part` on the current (re-measured) LP graph, under
    /// the configured framework.
    fn potential_of(&self, part: &Partition) -> f64 {
        match self.options.framework {
            Framework::A => global_cost::c0(&self.lp_graph, &self.machines, part, self.options.mu),
            Framework::B => {
                global_cost::c0_tilde(&self.lp_graph, &self.machines, part, self.options.mu)
            }
        }
    }

    /// Measure → estimate → install → refine (warm start) → migrate.
    /// Only the TCP-cluster path can fail; on error the cluster is
    /// deliberately left attached so the caller can diagnose the dead
    /// peers and recover over the survivors.
    pub(super) fn refine_once(
        &mut self,
        counters: &EpochCounters,
    ) -> Result<EpochRefinement, WireError> {
        let raw = weights::measure_epoch(&self.engine, counters);
        let estimated = self.estimator.estimate(&raw);
        weights::install(&mut self.lp_graph, &estimated);

        let mut part = self.engine.partition().clone();
        part.rebuild_aggregates(&self.lp_graph);
        let imbalance_before = part.imbalance(&self.machines);

        let (potential_before, potential_after, transfers, converged, overhead, refined) =
            match self.options.backend {
                RefineBackend::Sequential => match &self.options.racks {
                    None => {
                        let mut refine = RefineEngine::new(
                            &self.lp_graph,
                            &self.machines,
                            part,
                            self.options.mu,
                            self.options.framework,
                        )
                        .with_migration_charge(self.options.migration_charge);
                        let before = refine.potential();
                        let report = refine.run(&RefineOptions::default());
                        (
                            before,
                            report.final_potential,
                            report.transfers,
                            report.converged,
                            None,
                            refine.into_partition(),
                        )
                    }
                    Some(layout) => {
                        let (refined, report) = refine_hierarchical(
                            &self.lp_graph,
                            &self.machines,
                            part,
                            self.options.mu,
                            self.options.framework,
                            self.options.migration_charge,
                            layout,
                            &RefineOptions::default(),
                        );
                        (
                            report.potential_before,
                            report.potential_after,
                            report.transfers,
                            report.converged,
                            None,
                            refined,
                        )
                    }
                },
                RefineBackend::Distributed => {
                    let before = self.potential_of(&part);
                    let report = if self.cluster.is_some() {
                        let result = self
                            .cluster
                            .as_mut()
                            .expect("checked above")
                            .refine(&self.lp_graph, &self.machines, part);
                        match result {
                            Ok(report) => report,
                            // The cluster is left attached: the caller
                            // (`try_run_epoch`) first tries to recover
                            // from the last checkpoint, and tears it
                            // down only when recovery is impossible.
                            Err(e) => return Err(e),
                        }
                    } else {
                        let dist_opts = DistributedOptions {
                            mu: self.options.mu,
                            framework: self.options.framework,
                            migration_charge: self.options.migration_charge,
                            ..Default::default()
                        };
                        match &self.options.racks {
                            None => run_distributed(
                                Arc::new(self.lp_graph.clone()),
                                &self.machines,
                                part,
                                &dist_opts,
                            ),
                            Some(layout) => run_distributed_hierarchical(
                                Arc::new(self.lp_graph.clone()),
                                &self.machines,
                                part,
                                layout,
                                &dist_opts,
                            ),
                        }
                    };
                    let after = self.potential_of(&report.partition);
                    (
                        before,
                        after,
                        report.transfers,
                        report.converged,
                        Some(report.overhead),
                        report.partition,
                    )
                }
            };

        let imbalance_after = refined.imbalance(&self.machines);
        let charge = self.options.ticks_per_transfer * transfers as u64;
        self.refinements += 1;
        self.transfers += transfers;
        self.migration_ticks += charge;
        self.engine.set_partition(refined);
        Ok(EpochRefinement {
            potential_before,
            potential_after,
            transfers,
            migration_ticks: charge,
            migration_cost: self.options.migration_charge * transfers as f64,
            imbalance_before,
            imbalance_after,
            converged,
            overhead,
        })
    }

    /// Best-effort cluster teardown (Goodbye) so surviving workers
    /// exit immediately instead of waiting out their epoch timeout.
    pub(super) fn teardown_cluster(&mut self) {
        if let Some(cluster) = self.cluster.take() {
            let _ = cluster.shutdown();
        }
    }
}

/// Run a full closed loop from an App.-A hop-growth initial partition
/// (unit weights) — the `gtip dynamic` entry point.
pub fn run_closed_loop(
    graph: &Graph,
    machines: &MachineConfig,
    injections: Vec<Injection>,
    estimator: WeightEstimator,
    options: &DynamicOptions,
    rng: &mut Pcg32,
) -> DynamicReport {
    let initial = grow_partition(graph, machines, rng);
    let mut driver = DynamicDriver::new(
        graph,
        machines.clone(),
        initial,
        injections,
        estimator,
        options.clone(),
    );
    driver.run()
}

/// Frozen-vs-rebalanced comparison on an identical graph, workload and
/// initial partition — the headline §6.1 experiment.
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub frozen: DynamicReport,
    pub rebalanced: DynamicReport,
}

impl CompareReport {
    /// `frozen time / rebalanced time` (> 1 means rebalancing won).
    /// Both arms draining in zero ticks (an empty workload) is a tie:
    /// 1.0, not the 0.0 the naive `0 / max(1)` would report — and the
    /// denominator clamp can only engage in that same degenerate case,
    /// so it never silently skews a real comparison.
    pub fn speedup(&self) -> f64 {
        CompareReport::speedup_of(self.frozen.total_time(), self.rebalanced.total_time())
    }

    /// The speedup definition on bare totals — for callers (e.g. the
    /// churn sweep) that hold one frozen run against many rebalanced
    /// arms without assembling a `CompareReport` per pair.
    pub fn speedup_of(frozen_time: u64, rebalanced_time: u64) -> f64 {
        if frozen_time == 0 && rebalanced_time == 0 {
            return 1.0;
        }
        frozen_time as f64 / rebalanced_time.max(1) as f64
    }
}

/// Run both arms. The frozen arm keeps `initial` for the whole run; the
/// rebalanced arm closes the loop with `estimator` every `epoch_ticks`.
pub fn compare_frozen_vs_rebalanced(
    graph: &Graph,
    machines: &MachineConfig,
    initial: &Partition,
    injections: &[Injection],
    estimator: WeightEstimator,
    options: &DynamicOptions,
) -> CompareReport {
    let frozen_options = DynamicOptions { epoch_ticks: 0, ..options.clone() };
    let frozen = DynamicDriver::new(
        graph,
        machines.clone(),
        initial.clone(),
        injections.to_vec(),
        WeightEstimator::instantaneous(),
        frozen_options,
    )
    .run_owned();
    let rebalanced = DynamicDriver::new(
        graph,
        machines.clone(),
        initial.clone(),
        injections.to_vec(),
        estimator,
        options.clone(),
    )
    .run_owned();
    CompareReport { frozen, rebalanced }
}

impl<'g> DynamicDriver<'g> {
    /// `run()` for by-value call chains.
    pub fn run_owned(mut self) -> DynamicReport {
        self.run()
    }
}

//! Elastic membership for the closed loop (DESIGN.md §10): the shrink
//! half — diagnose dead workers, restore from the last epoch-boundary
//! checkpoint, re-home orphaned LPs, retry at K−1 — and the grow half,
//! admitting one queued joiner per epoch boundary. Both operate on the
//! attached TCP cluster and record what changed ([`RecoveryRecord`] /
//! [`AdmissionRecord`]) for the epoch report stream.

use crate::coordinator::net::ClusterLeader;
use crate::coordinator::WireError;
use crate::game::refine::rehome_assignment;
use crate::partition::{MachineConfig, MachineId};
use crate::sim::engine::SimEngine;
use crate::sim::snapshot::Snapshot;

use super::driver::{DynamicDriver, EpochRefinement, RefineBackend};

/// What a worker-death recovery did (DESIGN.md §10): which machines
/// were lost, how the fleet shrank, and how many orphaned LPs were
/// re-homed onto the survivors before the epoch's refinement re-ran.
#[derive(Debug, Clone)]
pub struct RecoveryRecord {
    /// Machines diagnosed dead, in the logical numbering the cluster
    /// used when each one died (a second death during the retry is
    /// recorded in the already-compacted numbering).
    pub dead_machines: Vec<MachineId>,
    /// Fleet size when the epoch started.
    pub machines_before: usize,
    /// Fleet size after the last recovery round of the epoch.
    pub machines_after: usize,
    /// LPs that lived on dead machines and were re-homed.
    pub rehomed_lps: usize,
}

/// What a worker admission did — the [`RecoveryRecord`] counterpart
/// for the grow direction (DESIGN.md §10): which wire id joined, the
/// logical slot it was inserted at, and how the fleet grew. The
/// joiner starts with zero LPs; the next refinement epoch migrates
/// load toward it (Thm 4.1 descent holds from any feasible start).
#[derive(Debug, Clone)]
pub struct AdmissionRecord {
    /// The joiner's immutable wire id (its `--machine-id`).
    pub joined_wire_id: MachineId,
    /// The logical machine slot the joiner was inserted at (wire ids
    /// stay ascending, so members to its right shifted up by one).
    pub joined_machine: MachineId,
    /// Fleet size before the admission.
    pub machines_before: usize,
    /// Fleet size after (always `machines_before + 1`).
    pub machines_after: usize,
    /// The joiner's self-reported relative speed (1.0 = an average
    /// member of the original fleet), before renormalization.
    pub speed: f64,
}

impl<'g> DynamicDriver<'g> {
    /// Route every distributed refinement over a connected TCP cluster
    /// (broadcasts the shared fixture to the workers first). Requires
    /// `options.backend == RefineBackend::Distributed`.
    pub fn attach_cluster(&mut self, mut cluster: ClusterLeader) -> Result<(), WireError> {
        assert_eq!(
            self.options.backend,
            RefineBackend::Distributed,
            "a TCP cluster needs the distributed backend"
        );
        if let Some(layout) = &self.options.racks {
            if let Err(e) = cluster.set_racks(layout.clone()) {
                let _ = cluster.shutdown();
                return Err(e);
            }
        }
        if let Err(e) = cluster.setup(&self.lp_graph, &self.machines) {
            // Best-effort Goodbye so workers that did complete the
            // handshake exit now instead of waiting out their derived
            // epoch-wait timeout.
            let _ = cluster.shutdown();
            return Err(e);
        }
        self.cluster = Some(cluster);
        Ok(())
    }

    /// A refinement over the TCP cluster failed: diagnose which
    /// workers died, restore the run from the last epoch-boundary
    /// checkpoint, shrink the fleet to the survivors (renormalizing
    /// their relative speeds), re-home the dead machines' LPs, and
    /// re-run this epoch's refinement at K−1 over the compacted
    /// cluster (DESIGN.md §10). Loops if another worker dies during
    /// the retry — each round shrinks the fleet, so it terminates.
    /// Tears the cluster down and propagates when recovery is
    /// impossible: no checkpoint, no peer actually dead (the failure
    /// was the leader's own), or the recovery handshake itself failed.
    pub(super) fn recover_and_refine(
        &mut self,
        mut err: WireError,
    ) -> Result<(EpochRefinement, RecoveryRecord), WireError> {
        let mut record: Option<RecoveryRecord> = None;
        loop {
            let Some(bytes) = self.last_checkpoint.clone() else {
                self.teardown_cluster();
                return Err(err);
            };
            let dead = match self.cluster.as_mut() {
                Some(cluster) => match cluster.diagnose_dead() {
                    // Every peer answered: the failure was not a
                    // worker death, so there is nothing to recover
                    // from — propagate the original error.
                    Ok(dead) if dead.is_empty() => {
                        self.teardown_cluster();
                        return Err(err);
                    }
                    Ok(dead) => dead,
                    Err(e) => {
                        self.teardown_cluster();
                        return Err(e);
                    }
                },
                None => return Err(err),
            };
            let snap = match Snapshot::decode(&bytes) {
                Ok(s) => s,
                Err(e) => {
                    self.teardown_cluster();
                    return Err(WireError::Protocol(format!("checkpoint unreadable: {e}")));
                }
            };
            let machines_before = snap.machine_count();
            debug_assert!(
                !dead.contains(&0) && dead.iter().all(|&m| m < machines_before),
                "dead set {dead:?} out of range for {machines_before} machines"
            );
            // Survivors keep their relative speeds, renormalized.
            let mut speeds: Vec<f64> = snap
                .speeds
                .iter()
                .enumerate()
                .filter(|(m, _)| !dead.contains(m))
                .map(|(_, &s)| s)
                .collect();
            let total: f64 = speeds.iter().sum();
            for s in &mut speeds {
                *s /= total;
            }
            let machines_after = MachineConfig::from_normalized(speeds);
            // Commit the survivors on the wire first (compact the
            // endpoint, broadcast Restore, await every ack) so local
            // state is only rebuilt once the cluster agreed.
            if let Err(e) =
                self.cluster.as_mut().expect("checked above").recover(&dead, &machines_after)
            {
                self.teardown_cluster();
                return Err(e);
            }
            // Restore game-side state from the checkpoint, re-home
            // the orphaned LPs, and rebuild the engine at K−1.
            self.lp_graph = snap.build_graph();
            self.estimator.import_state(snap.estimator.clone());
            self.refinements = snap.refinements as usize;
            self.transfers = snap.transfers as usize;
            self.migration_ticks = snap.migration_ticks;
            let (assignment, rehomed) =
                rehome_assignment(&snap.engine.assignment, &dead, &self.lp_graph, &machines_after);
            let mut state = snap.engine;
            state.assignment = assignment;
            self.engine = SimEngine::from_state(
                self.graph,
                machines_after.clone(),
                self.options.sim.clone(),
                state,
            );
            self.machines = machines_after;
            match &mut record {
                None => {
                    record = Some(RecoveryRecord {
                        dead_machines: dead.clone(),
                        machines_before,
                        machines_after: self.machines.count(),
                        rehomed_lps: rehomed,
                    })
                }
                Some(r) => {
                    r.dead_machines.extend(dead.iter().copied());
                    r.machines_after = self.machines.count();
                    r.rehomed_lps += rehomed;
                }
            }
            // Re-harvest the window the checkpoint preserved and
            // retry the refinement over the compacted cluster.
            // Checkpoint the restored K−1 state first: if *another*
            // worker dies during the retry, the next round must
            // restore in the new machine numbering.
            let counters = self.engine.take_epoch_counters();
            self.last_checkpoint = Some(self.snapshot().encode());
            match self.refine_once(&counters) {
                Ok(refinement) => {
                    // The post-refinement state is the new epoch
                    // boundary: `gtip dynamic --restore` on this file
                    // continues from here and (deterministically)
                    // reaches the same final state as this run. Named
                    // by recovery ordinal so a second recovery in the
                    // same run keeps the first's replay point intact.
                    let recovered = self.snapshot();
                    let encoded = recovered.encode();
                    self.write_checkpoint_file(
                        &format!("recovery-{:04}.snap", self.recovery_ordinal),
                        &encoded,
                    );
                    self.recovery_ordinal += 1;
                    self.last_checkpoint = Some(encoded);
                    return Ok((refinement, record.expect("at least one recovery round")));
                }
                Err(e) => err = e,
            }
        }
    }

    /// At an epoch boundary, admit one queued joiner if the attached
    /// cluster has one waiting — the grow half of elastic membership
    /// (DESIGN.md §10). Admission happens *only* here, never
    /// mid-epoch: the boundary is where a consistent state exists,
    /// and that state (remapped into the K+1 numbering) is exactly
    /// what the joiner receives as its `Catchup` payload. The joiner
    /// starts with zero LPs; the next refinement migrates load toward
    /// it under Thm 4.1's any-feasible-start descent, so no dedicated
    /// rebalancing pass is needed. A failed admission that rolled
    /// back cleanly returns `Ok(None)` and the run continues at K;
    /// `Err` means the rollback itself failed and the cluster was
    /// torn down.
    pub(super) fn try_admit_pending(&mut self) -> Result<Option<AdmissionRecord>, WireError> {
        let Some(cluster) = self.cluster.as_mut() else {
            return Ok(None);
        };
        let Some(req) = cluster.pending_join() else {
            return Ok(None);
        };
        let joined_wire = req.wire_id;
        let speed = req.speed;
        let machines_before = self.machines.clone();
        let k_old = machines_before.count();
        // Wire ids stay ascending in the logical numbering, so the
        // joiner lands at this slot and every member to its right
        // shifts up by one.
        let pos = cluster.joiner_position(joined_wire);
        // The joiner's self-reported speed is relative to an average
        // machine of the original fleet; the survivors' normalized
        // speeds sum to 1, so an average-sized share next to them is
        // speed/K. `from_speeds` renormalizes the grown vector.
        let mut weights: Vec<f64> = machines_before.speeds().to_vec();
        weights.insert(pos, speed / k_old as f64);
        let machines_after = MachineConfig::from_speeds(&weights);
        // Build the K+1 boundary snapshot the joiner catches up from:
        // the current engine state with every assignment at or right
        // of the insertion slot shifted into the grown numbering.
        let mut state = self.engine.capture_state();
        for a in &mut state.assignment {
            if *a >= pos {
                *a += 1;
            }
        }
        let snap = Snapshot {
            options: self.options.sim.clone(),
            node_weights: self.lp_graph.node_weights().to_vec(),
            edges: self.lp_graph.edges().collect(),
            speeds: machines_after.speeds().to_vec(),
            epoch: self.epoch_base + self.epochs.len() as u64,
            refinements: self.refinements as u64,
            transfers: self.transfers as u64,
            migration_ticks: self.migration_ticks,
            estimator: self.estimator.export_state(),
            rng_streams: Vec::new(),
            engine: state.clone(),
        };
        let encoded = snap.encode();
        let admitted =
            cluster.admit(req, &self.lp_graph, &machines_before, &machines_after, &encoded);
        match admitted {
            Ok(false) => Ok(None),
            Ok(true) => {
                // The cluster agreed on the wire; rebuild local state
                // at K+1 to match what the joiner received.
                self.engine = SimEngine::from_state(
                    self.graph,
                    machines_after.clone(),
                    self.options.sim.clone(),
                    state,
                );
                self.machines = machines_after;
                self.write_checkpoint_file(
                    &format!("admit-{:04}.snap", self.admission_ordinal),
                    &encoded,
                );
                self.admission_ordinal += 1;
                self.last_checkpoint = Some(encoded);
                eprintln!(
                    "gtip leader: admitted wire id {joined_wire} as machine {pos} \
                     ({k_old} -> {} machines)",
                    self.machines.count()
                );
                Ok(Some(AdmissionRecord {
                    joined_wire_id: joined_wire,
                    joined_machine: pos,
                    machines_before: k_old,
                    machines_after: self.machines.count(),
                    speed,
                }))
            }
            Err(e) => {
                self.teardown_cluster();
                Err(e)
            }
        }
    }
}

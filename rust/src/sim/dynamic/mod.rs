//! Closed-loop dynamic rebalancing (§6.1) — the paper's *title*
//! scenario, end to end.
//!
//! [`DynamicDriver`] alternates **simulation epochs** with **refinement
//! epochs**: run the optimistic PDES engine for `epoch_ticks` wall
//! ticks, harvest the per-LP measured loads of the window (events
//! processed, rollbacks, per-edge forward traffic — see
//! [`EpochCounters`]), turn them into fresh node/edge weights through a
//! pluggable [`WeightEstimator`], re-run the game-theoretic refinement
//! *warm-started from the current partition* (sequentially or through
//! the distributed machine-actor coordinator, see [`RefineBackend`]),
//! migrate the LPs on the live engine, and record an [`EpochReport`].
//!
//! Differences from the one-shot `sim::driver` loop kept for the Fig.
//! 7–10 harnesses: epoch-boundary (not modulo-tick) scheduling, windowed
//! activity measurement instead of instantaneous queue lengths only,
//! estimator smoothing/hysteresis to damp migration churn (cf. the
//! self-clustering partitioner of arXiv:1610.01295), a selectable
//! distributed backend, and a per-epoch report stream capturing the
//! potential descent of every refinement.
//!
//! [`EpochCounters`]: crate::sim::engine::EpochCounters

use crate::sim::snapshot::EstimatorState;
use crate::sim::weights::MeasuredWeights;

pub mod checkpoint;
pub mod driver;
pub mod membership;

pub use driver::{compare_frozen_vs_rebalanced, run_closed_loop, CompareReport, DynamicDriver};
pub use driver::{DynamicOptions, DynamicReport, EpochRefinement, EpochReport, RefineBackend};
pub use membership::{AdmissionRecord, RecoveryRecord};

/// How measured loads become refinement weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorKind {
    /// Use the latest window's measurement as-is.
    Instantaneous,
    /// Exponentially-weighted moving average across windows.
    Ewma,
    /// EWMA plus a relative dead band: the emitted weight only moves
    /// when the smoothed estimate drifts far enough, damping migration
    /// churn between epochs.
    Hysteresis,
}

impl std::str::FromStr for EstimatorKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "instant" | "instantaneous" => Ok(EstimatorKind::Instantaneous),
            "ewma" => Ok(EstimatorKind::Ewma),
            "hyst" | "hysteresis" => Ok(EstimatorKind::Hysteresis),
            other => Err(format!(
                "unknown estimator {other:?} (expected instant|ewma|hysteresis)"
            )),
        }
    }
}

impl std::fmt::Display for EstimatorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EstimatorKind::Instantaneous => "instant",
            EstimatorKind::Ewma => "ewma",
            EstimatorKind::Hysteresis => "hysteresis",
        })
    }
}

/// Stateful weight estimator fed one [`MeasuredWeights`] per epoch.
#[derive(Debug, Clone)]
pub struct WeightEstimator {
    kind: EstimatorKind,
    /// EWMA smoothing factor in `(0, 1]` (1 = no memory).
    alpha: f64,
    /// Relative dead band of the hysteresis variant.
    deadband: f64,
    node_state: Vec<f64>,
    edge_state: Vec<f64>,
    node_out: Vec<f64>,
    edge_out: Vec<f64>,
    primed: bool,
}

impl WeightEstimator {
    pub fn new(kind: EstimatorKind, alpha: f64, deadband: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of (0, 1]");
        assert!(deadband >= 0.0, "negative dead band");
        WeightEstimator {
            kind,
            alpha,
            deadband,
            node_state: Vec::new(),
            edge_state: Vec::new(),
            node_out: Vec::new(),
            edge_out: Vec::new(),
            primed: false,
        }
    }

    /// Pass-through estimator.
    pub fn instantaneous() -> Self {
        WeightEstimator::new(EstimatorKind::Instantaneous, 1.0, 0.0)
    }

    /// EWMA-smoothed estimator.
    pub fn ewma(alpha: f64) -> Self {
        WeightEstimator::new(EstimatorKind::Ewma, alpha, 0.0)
    }

    /// EWMA plus relative dead band.
    pub fn hysteresis(alpha: f64, deadband: f64) -> Self {
        WeightEstimator::new(EstimatorKind::Hysteresis, alpha, deadband)
    }

    /// Default parameters per kind (used by the CLI).
    pub fn of_kind(kind: EstimatorKind) -> Self {
        match kind {
            EstimatorKind::Instantaneous => WeightEstimator::instantaneous(),
            EstimatorKind::Ewma => WeightEstimator::ewma(0.5),
            EstimatorKind::Hysteresis => WeightEstimator::hysteresis(0.5, 0.25),
        }
    }

    pub fn kind(&self) -> EstimatorKind {
        self.kind
    }

    /// Smoothing memory for a checkpoint (`None` until the first
    /// window primes it; configuration is not state and is rebuilt
    /// from options on restore).
    pub fn export_state(&self) -> Option<EstimatorState> {
        if !self.primed {
            return None;
        }
        Some(EstimatorState {
            node_state: self.node_state.clone(),
            edge_state: self.edge_state.clone(),
            node_out: self.node_out.clone(),
            edge_out: self.edge_out.clone(),
            primed: self.primed,
        })
    }

    /// Adopt checkpointed smoothing memory verbatim (`None` resets to
    /// the unprimed initial state).
    pub fn import_state(&mut self, state: Option<EstimatorState>) {
        match state {
            None => {
                self.node_state.clear();
                self.edge_state.clear();
                self.node_out.clear();
                self.edge_out.clear();
                self.primed = false;
            }
            Some(s) => {
                self.node_state = s.node_state;
                self.edge_state = s.edge_state;
                self.node_out = s.node_out;
                self.edge_out = s.edge_out;
                self.primed = s.primed;
            }
        }
    }

    /// Fold one window's raw measurement into the estimate and return
    /// the weights to hand to the refinement engine.
    pub fn estimate(&mut self, raw: &MeasuredWeights) -> MeasuredWeights {
        if self.kind == EstimatorKind::Instantaneous {
            return raw.clone();
        }
        if !self.primed {
            self.node_state = raw.node_weights.clone();
            self.edge_state = raw.edge_weights.iter().map(|&(_, _, c)| c).collect();
            self.node_out = self.node_state.clone();
            self.edge_out = self.edge_state.clone();
            self.primed = true;
        } else {
            assert_eq!(self.node_state.len(), raw.node_weights.len(), "graph changed shape");
            assert_eq!(self.edge_state.len(), raw.edge_weights.len(), "graph changed shape");
            for (s, &x) in self.node_state.iter_mut().zip(&raw.node_weights) {
                *s = self.alpha * x + (1.0 - self.alpha) * *s;
            }
            for (s, &(_, _, c)) in self.edge_state.iter_mut().zip(&raw.edge_weights) {
                *s = self.alpha * c + (1.0 - self.alpha) * *s;
            }
            match self.kind {
                EstimatorKind::Ewma => {
                    self.node_out.copy_from_slice(&self.node_state);
                    self.edge_out.copy_from_slice(&self.edge_state);
                }
                EstimatorKind::Hysteresis => {
                    let band = self.deadband;
                    for (o, &s) in self.node_out.iter_mut().zip(&self.node_state) {
                        if (s - *o).abs() > band * 1.0f64.max(o.abs()) {
                            *o = s;
                        }
                    }
                    for (o, &s) in self.edge_out.iter_mut().zip(&self.edge_state) {
                        if (s - *o).abs() > band * 1.0f64.max(o.abs()) {
                            *o = s;
                        }
                    }
                }
                EstimatorKind::Instantaneous => unreachable!(),
            }
        }
        MeasuredWeights {
            node_weights: self.node_out.clone(),
            edge_weights: raw
                .edge_weights
                .iter()
                .zip(&self.edge_out)
                .map(|(&(u, v, _), &c)| (u, v, c))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::game::hierarchy::RackLayout;
    use crate::graph::generators::preferential_attachment;
    use crate::graph::Graph;
    use crate::partition::initial::grow_partition;
    use crate::partition::MachineConfig;
    use crate::sim::engine::SimOptions;
    use crate::sim::scenario::{Scenario, ScenarioKind, ScenarioOptions};
    use crate::sim::snapshot::Snapshot;
    use crate::util::rng::Pcg32;

    fn setup(seed: u64) -> (Graph, MachineConfig, Scenario) {
        let mut rng = Pcg32::new(seed);
        let g = preferential_attachment(120, 2, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let scenario = Scenario::build(
            ScenarioKind::HotspotShift,
            &g,
            &ScenarioOptions { threads: 60, horizon_ticks: 900, ..Default::default() },
            &mut rng,
        );
        (g, machines, scenario)
    }

    fn options(epoch_ticks: u64) -> DynamicOptions {
        DynamicOptions {
            sim: SimOptions { max_ticks: 200_000, ..Default::default() },
            epoch_ticks,
            ..Default::default()
        }
    }

    #[test]
    fn closed_loop_runs_refines_and_reports() {
        let (g, machines, scenario) = setup(1);
        let mut rng = Pcg32::new(2);
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &options(150),
            &mut rng,
        );
        assert!(!report.stats.truncated, "truncated: {:?}", report.stats);
        assert!(report.refinements() > 0, "no refinement epochs ran");
        assert_eq!(report.epochs.last().map(|e| e.tick_end), Some(report.stats.ticks));
        // Every refinement descends its potential (Thm 4.1).
        for e in &report.epochs {
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after <= r.potential_before + 1e-9,
                    "epoch {}: potential rose {} -> {}",
                    e.epoch,
                    r.potential_before,
                    r.potential_after
                );
                assert!(r.converged);
            }
        }
        // Epoch windows tile the run.
        for pair in report.epochs.windows(2) {
            assert_eq!(pair[0].tick_end, pair[1].tick_start);
        }
    }

    /// Singleton racks in the closed loop reproduce the flat run
    /// exactly: with one machine per rack the outer game IS the flat
    /// game and the guarded map-back is the identity, so every epoch's
    /// refinement — and therefore the whole simulation trajectory —
    /// is bit-identical (DESIGN.md §12).
    #[test]
    fn singleton_racks_closed_loop_matches_flat_exactly() {
        let (g, machines, scenario) = setup(7);
        let flat = run_closed_loop(
            &g,
            &machines,
            scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            &options(150),
            &mut Pcg32::new(8),
        );
        let mut opts = options(150);
        opts.racks = Some(RackLayout::singletons(machines.count()));
        let hier = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut Pcg32::new(8),
        );
        assert_eq!(hier.stats, flat.stats);
        assert_eq!(hier.transfers, flat.transfers);
        assert_eq!(hier.epochs.len(), flat.epochs.len());
        for (h, f) in hier.epochs.iter().zip(flat.epochs.iter()) {
            assert_eq!(h.events_processed, f.events_processed);
            assert_eq!(h.rollbacks, f.rollbacks);
            match (&h.refine, &f.refine) {
                (Some(hr), Some(fr)) => {
                    assert_eq!(hr.transfers, fr.transfers);
                    // Same partition; the flat arm reports the engine's
                    // incrementally-maintained potential while the
                    // hierarchical arm recomputes it fresh, so compare
                    // to rounding, not bits.
                    let tol = 1e-9 * (1.0 + fr.potential_after.abs());
                    assert!(
                        (hr.potential_after - fr.potential_after).abs() <= tol,
                        "epoch {}: potential {} vs {}",
                        h.epoch,
                        hr.potential_after,
                        fr.potential_after
                    );
                }
                (None, None) => {}
                other => panic!("epoch {} refine mismatch: {other:?}", h.epoch),
            }
        }
        assert_eq!(hier.epochs[0].racks, machines.count());
        assert_eq!(flat.epochs[0].racks, 0);
    }

    /// Real (non-singleton) racks: every epoch's two-level refinement
    /// still descends the flat potential (outer guarded map-back +
    /// Thm 4.1 on each scoped inner game), and the epoch reports carry
    /// the rack count.
    #[test]
    fn hierarchical_closed_loop_descends_every_epoch() {
        let (g, machines, scenario) = setup(9);
        let mut opts = options(150);
        opts.racks = Some(RackLayout::new(vec![0, 0, 1, 1]).unwrap());
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut Pcg32::new(10),
        );
        assert!(!report.stats.truncated);
        assert!(report.refinements() > 0, "no refinement epochs ran");
        for e in &report.epochs {
            assert_eq!(e.racks, 2);
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after <= r.potential_before + 1e-9,
                    "epoch {}: flat potential rose {} -> {}",
                    e.epoch,
                    r.potential_before,
                    r.potential_after
                );
            }
        }
    }

    #[test]
    fn frozen_mode_never_refines() {
        let (g, machines, scenario) = setup(3);
        let mut rng = Pcg32::new(4);
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &options(0),
            &mut rng,
        );
        assert_eq!(report.refinements(), 0);
        assert_eq!(report.transfers, 0);
        assert!(!report.stats.truncated);
        assert_eq!(report.epochs.len(), 1, "frozen run is one long epoch");
    }

    #[test]
    fn migration_charges_accumulate() {
        let (g, machines, scenario) = setup(5);
        let mut rng = Pcg32::new(6);
        let mut opts = options(150);
        opts.ticks_per_transfer = 3;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert_eq!(report.migration_ticks, 3 * report.transfers as u64);
        assert_eq!(report.total_time(), report.stats.ticks + report.migration_ticks);
        let per_epoch: u64 =
            report.epochs.iter().filter_map(|e| e.refine.as_ref()).map(|r| r.migration_ticks).sum();
        assert_eq!(per_epoch, report.migration_ticks);
    }

    /// The migration-time accounting seam (regression): epoch *wall*
    /// windows must tile `[0, total_time()]` exactly — each window is
    /// the sim window plus that epoch's migration stall — and
    /// throughput must divide by the stalled window, so per-epoch
    /// metrics and the headline metric bill migration identically.
    #[test]
    fn wall_windows_tile_total_time_and_throughput_bills_migration() {
        let (g, machines, scenario) = setup(11);
        let mut rng = Pcg32::new(12);
        let mut opts = options(150);
        opts.ticks_per_transfer = 4;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(report.migration_ticks > 0, "fixture produced no migration charge");
        assert_eq!(report.epochs.first().map(|e| e.wall_tick_start), Some(0));
        for pair in report.epochs.windows(2) {
            assert_eq!(pair[0].wall_tick_end, pair[1].wall_tick_start, "wall windows must tile");
            assert_eq!(pair[0].tick_end, pair[1].tick_start, "sim windows must tile");
        }
        assert_eq!(
            report.epochs.last().map(|e| e.wall_tick_end),
            Some(report.total_time()),
            "wall clock must end at the headline total"
        );
        for e in &report.epochs {
            assert_eq!(
                e.wall_tick_end - e.wall_tick_start,
                (e.tick_end - e.tick_start) + e.migration_ticks,
                "epoch {}: wall window != sim window + stall",
                e.epoch
            );
            assert_eq!(e.migration_ticks, e.refine.as_ref().map_or(0, |r| r.migration_ticks));
            let wall_window = (e.wall_tick_end - e.wall_tick_start).max(1);
            assert_eq!(
                e.throughput.to_bits(),
                (e.events_processed as f64 / wall_window as f64).to_bits(),
                "epoch {}: throughput must divide by the stalled window",
                e.epoch
            );
        }
        // total_time, windows, and throughput pinned together.
        let summed: u64 = report
            .epochs
            .iter()
            .map(|e| e.wall_tick_end - e.wall_tick_start)
            .sum();
        assert_eq!(summed, report.total_time());
    }

    /// `CompareReport::speedup` on the degenerate empty workload (both
    /// arms drain in zero ticks) is defined as 1.0, not 0.0.
    #[test]
    fn speedup_of_empty_workload_is_one() {
        let (g, machines, _) = setup(13);
        let mut rng = Pcg32::new(14);
        let initial = grow_partition(&g, &machines, &mut rng);
        let report = compare_frozen_vs_rebalanced(
            &g,
            &machines,
            &initial,
            &[], // no injections: both arms drain instantly
            WeightEstimator::instantaneous(),
            &options(150),
        );
        assert_eq!(report.frozen.total_time(), 0);
        assert_eq!(report.rebalanced.total_time(), 0);
        assert_eq!(report.speedup(), 1.0);
        // The bare-totals helper agrees with the method everywhere.
        assert_eq!(CompareReport::speedup_of(0, 0), 1.0);
        assert_eq!(CompareReport::speedup_of(100, 50), 2.0);
        assert_eq!(CompareReport::speedup_of(7, 0), 7.0);
    }

    /// The in-game charge prices moves inside the closed loop: every
    /// refinement epoch satisfies the augmented-descent guarantee
    /// `potential_after + migration_cost <= potential_before`, the
    /// per-epoch churn bound `transfers <= ΔΦ / (2·c_mig)` (framework A
    /// default), and `migration_cost` bills exactly charge × transfers.
    /// (The prohibitive-charge freeze and the free-vs-charged triple
    /// are covered end-to-end by
    /// `integration_dynamic::in_game_charge_reduces_churn_end_to_end`.)
    #[test]
    fn in_game_charge_damps_closed_loop_churn() {
        let (g, machines, scenario) = setup(15);
        let mut rng = Pcg32::new(16);
        let mut opts = options(150);
        opts.migration_charge = 50.0;
        let charged = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(charged.refinements() > 0, "loop never refined; test is vacuous");
        for e in &charged.epochs {
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after + r.migration_cost
                        <= r.potential_before + 1e-9 * (1.0 + r.potential_before.abs()),
                    "epoch {}: augmented descent violated: {} + {} > {}",
                    e.epoch,
                    r.potential_after,
                    r.migration_cost,
                    r.potential_before
                );
                assert_eq!(r.migration_cost, 50.0 * r.transfers as f64);
                // Churn bound theorem: each move drops the raw
                // potential by >= 2*c_mig under framework A.
                assert!(
                    r.transfers as f64
                        <= (r.potential_before - r.potential_after) / (2.0 * 50.0)
                            * (1.0 + 1e-9)
                            + 1e-9,
                    "epoch {}: churn bound violated",
                    e.epoch
                );
            }
        }
    }

    #[test]
    fn max_refinements_caps_the_loop() {
        let (g, machines, scenario) = setup(7);
        let mut rng = Pcg32::new(8);
        let mut opts = options(100);
        opts.max_refinements = 2;
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(report.refinements() <= 2);
        assert!(!report.stats.truncated);
    }

    #[test]
    fn distributed_backend_matches_sequential_loop() {
        let (g, machines, scenario) = setup(9);
        let mut opts = options(200);
        let mut rng = Pcg32::new(10);
        let initial = grow_partition(&g, &machines, &mut rng);

        let seq = DynamicDriver::new(
            &g,
            machines.clone(),
            initial.clone(),
            scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            opts.clone(),
        )
        .run_owned();

        opts.backend = RefineBackend::Distributed;
        let dist = DynamicDriver::new(
            &g,
            machines.clone(),
            initial,
            scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            opts,
        )
        .run_owned();

        // Same deterministic turn order => the whole closed loop agrees.
        assert_eq!(seq.stats.ticks, dist.stats.ticks);
        assert_eq!(seq.transfers, dist.transfers);
        assert_eq!(seq.epochs.len(), dist.epochs.len());
        // Only the message-passing backend accumulates sync overhead.
        assert!(seq.total_overhead().is_none());
        let overhead = dist.total_overhead().expect("distributed epochs measure overhead");
        assert!(overhead.total_messages() > 0);
        for (a, b) in seq.epochs.iter().zip(&dist.epochs) {
            match (&a.refine, &b.refine) {
                (Some(ra), Some(rb)) => {
                    assert_eq!(ra.transfers, rb.transfers);
                    assert!((ra.potential_after - rb.potential_after).abs() < 1e-6);
                }
                (None, None) => {}
                other => panic!("refinement schedule diverged: {other:?}"),
            }
        }
    }

    #[test]
    fn ewma_smooths_toward_new_signal() {
        let raw1 = MeasuredWeights {
            node_weights: vec![10.0, 0.0],
            edge_weights: vec![(0, 1, 4.0)],
        };
        let raw2 = MeasuredWeights {
            node_weights: vec![0.0, 10.0],
            edge_weights: vec![(0, 1, 0.0)],
        };
        let mut est = WeightEstimator::ewma(0.5);
        let first = est.estimate(&raw1);
        assert_eq!(first.node_weights, vec![10.0, 0.0], "first call primes");
        let second = est.estimate(&raw2);
        // Halfway between the two signals.
        assert!((second.node_weights[0] - 5.0).abs() < 1e-12);
        assert!((second.node_weights[1] - 5.0).abs() < 1e-12);
        assert!((second.edge_weights[0].2 - 2.0).abs() < 1e-12);
        // Repeated exposure converges to the new signal.
        for _ in 0..20 {
            est.estimate(&raw2);
        }
        let converged = est.estimate(&raw2);
        assert!((converged.node_weights[1] - 10.0).abs() < 1e-2);
    }

    #[test]
    fn hysteresis_holds_output_inside_deadband() {
        let raw = MeasuredWeights {
            node_weights: vec![10.0],
            edge_weights: vec![(0, 1, 10.0)],
        };
        let wiggle = MeasuredWeights {
            node_weights: vec![10.5],
            edge_weights: vec![(0, 1, 10.5)],
        };
        let jump = MeasuredWeights {
            node_weights: vec![30.0],
            edge_weights: vec![(0, 1, 30.0)],
        };
        let mut est = WeightEstimator::hysteresis(1.0, 0.25);
        let a = est.estimate(&raw);
        assert_eq!(a.node_weights[0], 10.0);
        // 5% wiggle stays inside the 25% dead band: output frozen.
        let b = est.estimate(&wiggle);
        assert_eq!(b.node_weights[0], 10.0);
        assert_eq!(b.edge_weights[0].2, 10.0);
        // A 3x jump breaks out.
        let c = est.estimate(&jump);
        assert_eq!(c.node_weights[0], 30.0);
        assert_eq!(c.edge_weights[0].2, 30.0);
    }

    #[test]
    fn charge_transfers_derives_the_in_game_price() {
        let opts = DynamicOptions::default().charge_transfers(3, 2.5);
        assert_eq!(opts.ticks_per_transfer, 3);
        assert_eq!(opts.migration_charge, 7.5);
        let free = DynamicOptions::default().charge_transfers(5, 0.0);
        assert_eq!(free.ticks_per_transfer, 5);
        assert_eq!(free.migration_charge, 0.0);
    }

    /// The driver-level checkpoint substrate: a snapshot taken at an
    /// epoch boundary re-encodes byte-identically through a decode,
    /// and a driver resumed from it finishes the run with exactly the
    /// same cumulative stats as the uninterrupted original.
    #[test]
    fn driver_snapshot_restores_and_continues_identically() {
        let (g, machines, scenario) = setup(21);
        let mut rng = Pcg32::new(22);
        let initial = grow_partition(&g, &machines, &mut rng);
        let opts = options(150);
        let mut live = DynamicDriver::new(
            &g,
            machines.clone(),
            initial,
            scenario.injections.clone(),
            WeightEstimator::ewma(0.5),
            opts.clone(),
        );
        assert!(live.try_run_epoch().unwrap(), "fixture drained before the checkpoint");
        assert!(live.try_run_epoch().unwrap(), "fixture drained before the checkpoint");

        let snap = live.snapshot();
        let bytes = snap.encode();
        let decoded = Snapshot::decode(&bytes).expect("decode");
        assert_eq!(bytes, decoded.encode(), "save -> load -> save must be byte-identical");
        assert!(decoded.estimator.is_some(), "two epochs must prime the EWMA");

        let g2 = decoded.build_graph();
        let mut restored =
            DynamicDriver::from_snapshot(&g2, &decoded, WeightEstimator::ewma(0.5), opts);
        let restored_report = restored.run();
        let live_report = live.run();
        assert_eq!(live_report.stats, restored_report.stats);
        assert_eq!(live_report.transfers, restored_report.transfers);
        assert_eq!(live_report.migration_ticks, restored_report.migration_ticks);
        assert_eq!(live_report.total_time(), restored_report.total_time());
        // The live run keeps its pre-checkpoint epoch reports; the
        // restored run renumbers from the checkpoint. The tails match.
        assert_eq!(live_report.epochs.len(), restored_report.epochs.len() + 2);
        for (a, b) in live_report.epochs[2..].iter().zip(&restored_report.epochs) {
            assert_eq!(a.tick_start, b.tick_start);
            assert_eq!(a.tick_end, b.tick_end);
            assert_eq!(a.events_processed, b.events_processed);
            assert_eq!(a.refine.is_some(), b.refine.is_some());
            if let (Some(ra), Some(rb)) = (&a.refine, &b.refine) {
                assert_eq!(ra.transfers, rb.transfers);
                assert_eq!(ra.potential_after.to_bits(), rb.potential_after.to_bits());
            }
        }
    }

    /// `checkpoint_dir` materializes one snapshot per epoch boundary,
    /// each readable and byte-stable through a decode/encode cycle.
    #[test]
    fn checkpoint_dir_writes_epoch_snapshots() {
        let dir = std::env::temp_dir().join(format!("gtip-ckpt-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (g, machines, scenario) = setup(23);
        let mut rng = Pcg32::new(24);
        let mut opts = options(150);
        opts.checkpoint_dir = Some(dir.clone());
        let report = run_closed_loop(
            &g,
            &machines,
            scenario.injections,
            WeightEstimator::instantaneous(),
            &opts,
            &mut rng,
        );
        assert!(report.refinements() > 0);
        let first = dir.join("epoch-0000.snap");
        let snap = Snapshot::read_from(&first).expect("first epoch checkpoint must exist");
        assert_eq!(snap.epoch, 0);
        assert_eq!(snap.machine_count(), machines.count());
        assert_eq!(snap.encode(), std::fs::read(&first).unwrap(), "file is canonical bytes");
        // One file per epoch boundary that was checkpointed.
        let count = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(count, report.epochs.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A run resumed from a snapshot into the *same* `checkpoint_dir`
    /// continues the `epoch-NNNN.snap` sequence from the cumulative
    /// epoch counter instead of renumbering from zero and silently
    /// overwriting the original run's files.
    #[test]
    fn restored_run_extends_checkpoint_sequence_without_overwriting() {
        let dir = std::env::temp_dir().join(format!("gtip-ckpt-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (g, machines, scenario) = setup(29);
        let mut rng = Pcg32::new(30);
        let initial = grow_partition(&g, &machines, &mut rng);
        let mut opts = options(150);
        opts.checkpoint_dir = Some(dir.clone());
        let mut live = DynamicDriver::new(
            &g,
            machines.clone(),
            initial,
            scenario.injections.clone(),
            WeightEstimator::ewma(0.5),
            opts.clone(),
        );
        assert!(live.try_run_epoch().unwrap(), "fixture drained before the checkpoint");
        assert!(live.try_run_epoch().unwrap(), "fixture drained before the checkpoint");
        let snap = live.snapshot();
        assert_eq!(snap.epoch, 2, "two boundaries passed");
        let originals: Vec<Vec<u8>> = (0..2)
            .map(|e| std::fs::read(dir.join(format!("epoch-{e:04}.snap"))).expect("original snap"))
            .collect();

        let g2 = snap.build_graph();
        let mut restored =
            DynamicDriver::from_snapshot(&g2, &snap, WeightEstimator::ewma(0.5), opts);
        let report = restored.run();
        assert!(!report.epochs.is_empty(), "the resumed run must do work");
        assert!(
            dir.join("epoch-0002.snap").exists(),
            "the resumed run's first boundary continues the cumulative sequence"
        );
        for (e, bytes) in originals.iter().enumerate() {
            assert_eq!(
                &std::fs::read(dir.join(format!("epoch-{e:04}.snap"))).unwrap(),
                bytes,
                "the original run's epoch-{e:04}.snap must survive the resumed run"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn estimator_and_backend_parse_from_strings() {
        assert_eq!("ewma".parse::<EstimatorKind>().unwrap(), EstimatorKind::Ewma);
        assert_eq!(
            "hysteresis".parse::<EstimatorKind>().unwrap(),
            EstimatorKind::Hysteresis
        );
        assert!("nope".parse::<EstimatorKind>().is_err());
        assert_eq!("sequential".parse::<RefineBackend>().unwrap(), RefineBackend::Sequential);
        assert_eq!("dist".parse::<RefineBackend>().unwrap(), RefineBackend::Distributed);
        assert!("p2p".parse::<RefineBackend>().is_err());
    }
}

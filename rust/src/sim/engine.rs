//! The tick-driven optimistic simulation engine (paper Fig. 6).
//!
//! Owns all LPs, the LP-to-machine assignment, and the wall-clock loop:
//!
//! 1. fossil-collect against GVT,
//! 2. idle LPs select + start their lowest-timestamped ready event
//!    (stragglers roll back, anti-messages cascade),
//! 3. busy LPs tick down; completed forwarding events flood to unseen
//!    neighbors (cross-machine forwards pay the `event-tick` delay),
//! 4. pending-event delays decrement, GVT updates,
//! 5. injections scheduled for this tick arrive.
//!
//! Processing an event occupies the LP for
//! `ceil(resident_LPs × base_time / (w_k · K))` ticks — machine speed
//! inversely proportional to resident LP count (§6.1), generalized to
//! heterogeneous speeds `w_k`.

use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};
use crate::sim::event::{Event, EventKind, SimTime, WallTime};
use crate::sim::lp::{Lp, StartOutcome};
use crate::util::stats::Trace;

/// Static engine options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Base process time of a normal event (wall ticks).
    pub base_process_time: WallTime,
    /// Base process time of a rollback event.
    pub rollback_process_time: WallTime,
    /// Wall-clock delay of a cross-machine event transfer.
    pub inter_machine_delay: WallTime,
    /// Wall-clock delay of an intra-machine event transfer.
    pub intra_machine_delay: WallTime,
    /// Simulation-time latency per flood hop.
    pub hop_latency: SimTime,
    /// Record machine-load traces every this many ticks (0 = never).
    pub trace_every: WallTime,
    /// Safety cap on wall ticks.
    pub max_ticks: WallTime,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            base_process_time: 1,
            rollback_process_time: 1,
            inter_machine_delay: 3,
            intra_machine_delay: 0,
            hop_latency: 1,
            trace_every: 0,
            max_ticks: 2_000_000,
        }
    }
}

/// Aggregate statistics of a run.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Total wall-clock ticks consumed so far — the paper's headline
    /// *simulation time* metric.
    pub ticks: WallTime,
    pub events_processed: u64,
    pub events_forwarded: u64,
    pub cross_machine_forwards: u64,
    pub rollbacks: u64,
    pub antimessages_sent: u64,
    /// True if the run hit `max_ticks` before draining.
    pub truncated: bool,
}

/// A scheduled packet injection: `(wall_tick, lp, event)`.
#[derive(Debug, Clone, Copy)]
pub struct Injection {
    pub at_tick: WallTime,
    pub lp: NodeId,
    pub event: Event,
}

/// Per-LP / per-edge activity accumulated since the last harvest — the
/// measured load signals (§6.1) the closed-loop rebalancer
/// (`sim::dynamic`) feeds to its weight estimators. Global [`SimStats`]
/// counters are cumulative; these reset at every
/// [`SimEngine::take_epoch_counters`] call.
#[derive(Debug, Clone, Default)]
pub struct EpochCounters {
    /// Wall ticks covered by this window.
    pub ticks: WallTime,
    /// Events completed per LP (including rollback processing).
    pub events_by_lp: Vec<u64>,
    /// Rollback episodes per LP.
    pub rollbacks_by_lp: Vec<u64>,
    /// Cross-machine forwards originated per LP.
    pub cross_forwards_by_lp: Vec<u64>,
    /// Forwards per directed half-edge, aligned with the graph's CSR
    /// slots (`Graph::row_offset(u) + k` = `u`'s `k`-th neighbor) — a
    /// flat add on the hot path instead of a hash lookup.
    pub forwards_by_half_edge: Vec<u64>,
}

impl EpochCounters {
    fn for_graph(graph: &Graph) -> Self {
        let n = graph.node_count();
        EpochCounters {
            ticks: 0,
            events_by_lp: vec![0; n],
            rollbacks_by_lp: vec![0; n],
            cross_forwards_by_lp: vec![0; n],
            forwards_by_half_edge: vec![0; graph.half_edge_count()],
        }
    }

    /// Forwards that crossed edge `{u, v}` (either direction) during
    /// the window.
    pub fn forwards_on(&self, graph: &Graph, u: NodeId, v: NodeId) -> u64 {
        let uv = graph.half_edge_index(u, v).map_or(0, |s| self.forwards_by_half_edge[s]);
        let vu = graph.half_edge_index(v, u).map_or(0, |s| self.forwards_by_half_edge[s]);
        uv + vu
    }

    /// Total events completed during the window.
    pub fn events_total(&self) -> u64 {
        self.events_by_lp.iter().sum()
    }

    /// Total rollback episodes during the window.
    pub fn rollbacks_total(&self) -> u64 {
        self.rollbacks_by_lp.iter().sum()
    }

    /// Total cross-machine forwards during the window.
    pub fn cross_forwards_total(&self) -> u64 {
        self.cross_forwards_by_lp.iter().sum()
    }
}

/// The engine.
pub struct SimEngine<'g> {
    graph: &'g Graph,
    machines: MachineConfig,
    part: Partition,
    lps: Vec<Lp>,
    options: SimOptions,
    stats: SimStats,
    gvt: SimTime,
    /// Injections sorted descending by tick (pop from the back).
    injections: Vec<Injection>,
    /// Machine-load traces (avg queue length per resident LP), Figs 9/10.
    load_traces: Vec<Trace>,
    /// Activity window since the last `take_epoch_counters` harvest.
    epoch: EpochCounters,
    /// Scratch buffer for messages produced within a tick.
    outbox: Vec<(NodeId, Event)>,
}

impl<'g> SimEngine<'g> {
    pub fn new(
        graph: &'g Graph,
        machines: MachineConfig,
        part: Partition,
        options: SimOptions,
        mut injections: Vec<Injection>,
    ) -> Self {
        assert_eq!(part.node_count(), graph.node_count());
        assert_eq!(part.machine_count(), machines.count());
        injections.sort_by_key(|inj| std::cmp::Reverse(inj.at_tick));
        let load_traces = (0..machines.count())
            .map(|k| Trace::new(format!("machine{k}")))
            .collect();
        SimEngine {
            graph,
            lps: vec![Lp::default(); graph.node_count()],
            machines,
            part,
            options,
            stats: SimStats::default(),
            gvt: 0,
            injections,
            load_traces,
            epoch: EpochCounters::for_graph(graph),
            outbox: Vec::new(),
        }
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    pub fn partition(&self) -> &Partition {
        &self.part
    }

    pub fn graph(&self) -> &Graph {
        self.graph
    }

    pub fn lps(&self) -> &[Lp] {
        &self.lps
    }

    pub fn gvt(&self) -> SimTime {
        self.gvt
    }

    pub fn load_traces(&self) -> &[Trace] {
        &self.load_traces
    }

    /// Activity accumulated since the last [`Self::take_epoch_counters`]
    /// harvest (or engine construction).
    pub fn epoch_counters(&self) -> &EpochCounters {
        &self.epoch
    }

    /// Harvest the per-epoch activity counters, resetting the window —
    /// the measurement hook of the closed rebalancing loop (§6.1).
    pub fn take_epoch_counters(&mut self) -> EpochCounters {
        let fresh = EpochCounters::for_graph(self.graph);
        std::mem::replace(&mut self.epoch, fresh)
    }

    /// Replace the LP-to-machine assignment (the dynamic-refinement hook;
    /// event transfer semantics change immediately, matching the paper's
    /// model where migration cost is ignored).
    pub fn set_partition(&mut self, part: Partition) {
        assert_eq!(part.node_count(), self.graph.node_count());
        self.part = part;
    }

    /// Busy time charged on machine `k` for an event of kind `kind`:
    /// `resident × base / (w_k · K)`, rounded up, minimum 1.
    fn occupancy_cost(&self, k: MachineId, kind: EventKind) -> WallTime {
        let base =
            kind.base_process_time(self.options.base_process_time, self.options.rollback_process_time);
        let resident = self.part.count(k) as f64;
        let speed_scale = self.machines.speed(k) * self.machines.count() as f64;
        ((resident * base as f64 / speed_scale).ceil() as WallTime).max(1)
    }

    /// Transfer delay between two LPs given the current assignment.
    fn transfer_delay(&self, from: NodeId, to: NodeId) -> WallTime {
        if self.part.machine_of(from) == self.part.machine_of(to) {
            self.options.intra_machine_delay
        } else {
            self.options.inter_machine_delay
        }
    }

    /// Deliver any injections scheduled at `tick`.
    fn deliver_injections(&mut self, tick: WallTime) {
        while let Some(inj) = self.injections.last().copied() {
            if inj.at_tick > tick {
                break;
            }
            self.injections.pop();
            self.lps[inj.lp].receive(inj.event);
        }
    }

    /// Compute GVT: minimum over all LP local times of *busy* LPs and all
    /// pending event timestamps (Fig. 6 / Table III `global-time`).
    fn compute_gvt(&self) -> SimTime {
        let mut gvt = SimTime::MAX;
        for lp in &self.lps {
            if let Some(b) = &lp.busy {
                gvt = gvt.min(b.event.time);
            }
            if let Some(t) = lp.min_pending_time() {
                gvt = gvt.min(t);
            }
        }
        // Events not yet injected also hold back GVT.
        for inj in &self.injections {
            gvt = gvt.min(inj.event.time);
        }
        if gvt == SimTime::MAX {
            // Drained: GVT is the max local time.
            self.lps.iter().map(|l| l.local_time).max().unwrap_or(0)
        } else {
            gvt
        }
    }

    /// Record machine load (mean queue length per resident LP, §6.1) at
    /// the current tick.
    fn record_loads(&mut self) {
        let k = self.machines.count();
        let mut sums = vec![0.0f64; k];
        for (i, lp) in self.lps.iter().enumerate() {
            sums[self.part.machine_of(i)] += lp.queue_len() as f64;
        }
        for m in 0..k {
            let cnt = self.part.count(m).max(1) as f64;
            self.load_traces[m].push(self.stats.ticks as f64, sums[m] / cnt);
        }
    }

    /// All work drained (and no injections outstanding)?
    pub fn drained(&self) -> bool {
        self.injections.is_empty() && self.lps.iter().all(|lp| lp.idle_and_empty())
    }

    /// Execute one wall-clock tick (Fig. 6 body). Returns `false` once
    /// drained.
    pub fn step(&mut self) -> bool {
        if self.drained() {
            return false;
        }
        let tick = self.stats.ticks;
        self.deliver_injections(tick);

        // Phase 1: idle LPs select + start events; busy LPs tick down and
        // completed events flood forward. Messages buffer in the outbox so
        // intra-tick ordering does not depend on LP index.
        let n = self.graph.node_count();
        let mut outbox = std::mem::take(&mut self.outbox);
        outbox.clear();
        for i in 0..n {
            let machine = self.part.machine_of(i);
            if self.lps[i].busy.is_none() {
                let cost_rollback = self.occupancy_cost(machine, EventKind::Rollback);
                let cost_normal = self.occupancy_cost(machine, EventKind::ProcessForward);
                let outcome = self.lps[i].start_next(
                    |kind| match kind {
                        EventKind::Rollback => cost_rollback,
                        _ => cost_normal,
                    },
                    self.options.inter_machine_delay,
                );
                match outcome {
                    StartOutcome::Nothing => {}
                    StartOutcome::Started { rolled_back, cancellations }
                    | StartOutcome::RolledBack { rolled_back, cancellations } => {
                        if rolled_back > 0 {
                            self.epoch.rollbacks_by_lp[i] += 1;
                        }
                        self.stats.antimessages_sent += cancellations.len() as u64;
                        for (nb, ev) in cancellations {
                            // Anti-message delay follows the link type.
                            let mut ev = ev;
                            ev.tick = self.transfer_delay(i, nb);
                            outbox.push((nb, ev));
                        }
                    }
                }
            }
            if let Some(done) = self.lps[i].tick_busy() {
                match done.kind {
                    EventKind::Rollback => {
                        // Anti-message consumed; nothing retires to history.
                        self.stats.events_processed += 1;
                        self.epoch.events_by_lp[i] += 1;
                    }
                    _ => {
                        self.stats.events_processed += 1;
                        self.epoch.events_by_lp[i] += 1;
                        let mut forwarded_to = Vec::new();
                        if done.count > 0 {
                            let row = self.graph.row_offset(i);
                            for (slot, &nb) in self.graph.neighbors(i).iter().enumerate() {
                                if !self.lps[nb].has_seen(done.thread) {
                                    let delay = self.transfer_delay(i, nb);
                                    let fwd = done.forwarded(self.options.hop_latency, delay);
                                    outbox.push((nb, fwd));
                                    forwarded_to.push(nb);
                                    self.stats.events_forwarded += 1;
                                    self.epoch.forwards_by_half_edge[row + slot] += 1;
                                    if self.part.machine_of(nb) != machine {
                                        self.stats.cross_machine_forwards += 1;
                                        self.epoch.cross_forwards_by_lp[i] += 1;
                                    }
                                }
                            }
                        }
                        self.lps[i].retire(done, forwarded_to);
                    }
                }
            }
        }

        // Phase 2: deliver buffered messages.
        for (nb, ev) in outbox.drain(..) {
            // Receivers that already saw the thread (race within the tick)
            // drop duplicate forwards.
            if ev.kind != EventKind::Rollback && self.lps[nb].has_seen(ev.thread) {
                continue;
            }
            self.lps[nb].receive(ev);
        }
        self.outbox = outbox;

        // Phase 3: delays tick down, GVT advances, fossils collected.
        for lp in &mut self.lps {
            lp.tick_delays();
        }
        self.gvt = self.compute_gvt();
        for lp in &mut self.lps {
            lp.fossil_collect(self.gvt);
        }

        self.stats.ticks += 1;
        self.epoch.ticks += 1;
        self.stats.rollbacks = self.lps.iter().map(|l| l.rollbacks).sum();
        if self.options.trace_every > 0 && tick % self.options.trace_every == 0 {
            self.record_loads();
        }
        true
    }

    /// Run until drained or `max_ticks`. Returns final stats.
    pub fn run_to_completion(&mut self) -> SimStats {
        while self.stats.ticks < self.options.max_ticks {
            if !self.step() {
                break;
            }
        }
        if !self.drained() {
            self.stats.truncated = true;
        }
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn line_graph(n: usize) -> Graph {
        let mut b = GraphBuilder::with_nodes(n);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1, 1.0);
        }
        b.build()
    }

    fn engine_on(
        graph: &Graph,
        k: usize,
        assignment: Vec<usize>,
        injections: Vec<Injection>,
        options: SimOptions,
    ) -> SimEngine<'_> {
        let machines = MachineConfig::homogeneous(k);
        let part = Partition::from_assignment(graph, k, assignment);
        SimEngine::new(graph, machines, part, options, injections)
    }

    #[test]
    fn single_event_drains() {
        let g = line_graph(3);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 0) }];
        let mut e = engine_on(&g, 1, vec![0, 0, 0], inj, SimOptions::default());
        let stats = e.run_to_completion();
        assert!(!stats.truncated);
        assert_eq!(stats.events_processed, 1);
        assert_eq!(stats.events_forwarded, 0);
        assert!(e.drained());
    }

    #[test]
    fn flood_covers_hop_limit() {
        // Line 0-1-2-3-4, flood from node 0 with 2 hops: reaches 0,1,2.
        let g = line_graph(5);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 2) }];
        let mut e = engine_on(&g, 1, vec![0; 5], inj, SimOptions::default());
        let stats = e.run_to_completion();
        assert!(!stats.truncated);
        assert_eq!(stats.events_processed, 3, "nodes 0,1,2 each process once");
        assert_eq!(stats.events_forwarded, 2);
        assert_eq!(stats.rollbacks, 0);
    }

    #[test]
    fn flood_branches_to_all_unseen_neighbors() {
        // Star: center 0 with 4 leaves; 1 hop floods to all leaves.
        let mut b = GraphBuilder::with_nodes(5);
        for leaf in 1..5 {
            b.add_edge(0, leaf, 1.0);
        }
        let g = b.build();
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 1) }];
        let mut e = engine_on(&g, 1, vec![0; 5], inj, SimOptions::default());
        let stats = e.run_to_completion();
        assert_eq!(stats.events_processed, 5);
        assert_eq!(stats.events_forwarded, 4);
    }

    #[test]
    fn no_duplicate_delivery_on_cycles() {
        // Triangle: flood with large hop budget must visit each LP once.
        let mut b = GraphBuilder::with_nodes(3);
        b.add_edge(0, 1, 1.0).add_edge(1, 2, 1.0).add_edge(0, 2, 1.0);
        let g = b.build();
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 10) }];
        let mut e = engine_on(&g, 1, vec![0; 3], inj, SimOptions::default());
        let stats = e.run_to_completion();
        assert_eq!(stats.events_processed, 3, "each LP exactly once");
    }

    #[test]
    fn cross_machine_forwards_counted_and_slower() {
        let g = line_graph(4);
        let inj = || vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 3) }];
        // Two residents per machine in both configs so occupancy costs
        // match and only the transfer delays differ.
        // Contiguous halves: one crossing (edge 1-2).
        let mut same = engine_on(&g, 2, vec![0, 0, 1, 1], inj(), SimOptions::default());
        let s1 = same.run_to_completion();
        assert_eq!(s1.cross_machine_forwards, 1);
        // Alternating machines: every forward crosses.
        let mut alt = engine_on(&g, 2, vec![0, 1, 0, 1], inj(), SimOptions::default());
        let s2 = alt.run_to_completion();
        assert_eq!(s2.cross_machine_forwards, 3);
        assert!(
            s2.ticks > s1.ticks,
            "cross-machine delays must slow the run: {} vs {}",
            s2.ticks,
            s1.ticks
        );
    }

    #[test]
    fn occupancy_scales_with_resident_lps() {
        // 10 LPs on one machine: each event takes 10 ticks of busy time,
        // so a single flood over a line is much slower than with 2 LPs.
        let g = line_graph(10);
        let inj = || vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 0) }];
        let mut crowded = engine_on(&g, 1, vec![0; 10], inj(), SimOptions::default());
        let c = crowded.run_to_completion();
        // The single event costs ceil(10×1/1) = 10 busy ticks.
        assert!(c.ticks >= 10, "crowded machine too fast: {} ticks", c.ticks);
    }

    #[test]
    fn straggler_causes_rollback_cross_machine() {
        // LP1 receives a fast local event chain advancing its clock, then
        // a delayed cross-machine event with an older timestamp arrives.
        let mut b = GraphBuilder::with_nodes(3);
        b.add_edge(0, 1, 1.0).add_edge(1, 2, 1.0);
        let g = b.build();
        let injections = vec![
            // Thread 1: starts at LP2 (same machine as LP1), timestamp 10,
            // floods to LP1 quickly.
            Injection { at_tick: 0, lp: 2, event: Event::injection(1, 10, 1) },
            // Thread 2: starts at LP0 (other machine), OLD timestamp 1,
            // floods to LP1 but arrives late due to inter-machine delay.
            Injection { at_tick: 0, lp: 0, event: Event::injection(2, 1, 1) },
        ];
        let opts = SimOptions { inter_machine_delay: 8, ..Default::default() };
        let mut e = engine_on(&g, 2, vec![1, 0, 0], injections, opts);
        let stats = e.run_to_completion();
        assert!(stats.rollbacks > 0, "expected a straggler rollback; stats: {stats:?}");
        assert!(!stats.truncated);
    }

    #[test]
    fn repartition_mid_run_changes_delays() {
        let g = line_graph(6);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 5) }];
        let machines = MachineConfig::homogeneous(2);
        let part = Partition::from_assignment(&g, 2, vec![0, 1, 0, 1, 0, 1]);
        let mut e = SimEngine::new(&g, machines, part, SimOptions::default(), inj);
        // After a few ticks, collapse everything onto machine 0.
        for _ in 0..3 {
            e.step();
        }
        let better = Partition::from_assignment(&g, 2, vec![0; 6]);
        e.set_partition(better);
        let stats = e.run_to_completion();
        assert!(!stats.truncated);
        assert!(e.drained());
    }

    #[test]
    fn load_traces_recorded() {
        let g = line_graph(4);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 3) }];
        let opts = SimOptions { trace_every: 1, ..Default::default() };
        let mut e = engine_on(&g, 2, vec![0, 0, 1, 1], inj, opts);
        let _ = e.run_to_completion();
        assert_eq!(e.load_traces().len(), 2);
        assert!(e.load_traces()[0].len() > 0);
    }

    #[test]
    fn gvt_monotone_nondecreasing() {
        let g = line_graph(8);
        let injections: Vec<Injection> = (0..4)
            .map(|t| Injection {
                at_tick: t * 2,
                lp: (t as usize) * 2,
                event: Event::injection(t + 1, t * 5, 2),
            })
            .collect();
        let mut e = engine_on(&g, 2, vec![0, 0, 0, 0, 1, 1, 1, 1], injections, SimOptions::default());
        let mut last_gvt = 0;
        while e.step() {
            assert!(e.gvt() >= last_gvt, "GVT regressed: {} -> {}", last_gvt, e.gvt());
            last_gvt = e.gvt();
        }
    }

    #[test]
    fn epoch_counters_track_activity_and_reset() {
        let g = line_graph(4);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 3) }];
        let mut e = engine_on(&g, 2, vec![0, 0, 1, 1], inj, SimOptions::default());
        let stats = e.run_to_completion();
        let c = e.epoch_counters();
        assert_eq!(c.events_total(), stats.events_processed);
        assert_eq!(c.cross_forwards_total(), stats.cross_machine_forwards);
        assert_eq!(
            c.forwards_on(&g, 0, 1) + c.forwards_on(&g, 1, 2) + c.forwards_on(&g, 2, 3),
            stats.events_forwarded
        );
        assert_eq!(c.ticks, stats.ticks);
        let taken = e.take_epoch_counters();
        assert_eq!(taken.events_total(), stats.events_processed);
        assert_eq!(e.epoch_counters().events_total(), 0);
        assert_eq!(e.epoch_counters().ticks, 0);
        assert!(e.epoch_counters().forwards_by_half_edge.iter().all(|&x| x == 0));
    }

    #[test]
    fn late_injections_arrive() {
        let g = line_graph(3);
        let injections = vec![
            Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 0) },
            Injection { at_tick: 50, lp: 2, event: Event::injection(2, 100, 0) },
        ];
        let mut e = engine_on(&g, 1, vec![0; 3], injections, SimOptions::default());
        let stats = e.run_to_completion();
        assert_eq!(stats.events_processed, 2);
        assert!(stats.ticks > 50);
    }
}

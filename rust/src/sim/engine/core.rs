//! The engine core: the occupancy/transfer cost helpers, the bitset
//! worklist, the raw-pointer parallel phase-1 machinery, and
//! [`SimEngine`] itself — construction, the tick loop (sequential and
//! parallel), GVT, fossil collection, and snapshot capture/restore.
//! The configuration and measurement types it exchanges with drivers
//! ([`SimOptions`], [`SimStats`], [`Injection`], [`EpochCounters`])
//! live in the parent module.

use std::sync::Barrier;

use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};
use crate::sim::event::{Event, EventKind, SimTime, WallTime};
use crate::sim::lp::{Lp, StartOutcome};
use crate::util::stats::Trace;

use super::{EpochCounters, Injection, SimOptions, SimStats};

/// Busy time charged on machine `k` for an event of kind `kind`:
/// `resident × base / (w_k · K)`, rounded up, minimum 1. Free function
/// used to (re)build the per-machine cost cache.
fn occupancy_cost(
    part: &Partition,
    machines: &MachineConfig,
    options: &SimOptions,
    k: MachineId,
    kind: EventKind,
) -> WallTime {
    let base =
        kind.base_process_time(options.base_process_time, options.rollback_process_time);
    let resident = part.count(k) as f64;
    let speed_scale = machines.speed(k) * machines.count() as f64;
    ((resident * base as f64 / speed_scale).ceil() as WallTime).max(1)
}

/// Transfer delay between two LPs given the current assignment.
fn transfer_delay(part: &Partition, options: &SimOptions, from: NodeId, to: NodeId) -> WallTime {
    if part.machine_of(from) == part.machine_of(to) {
        options.intra_machine_delay
    } else {
        options.inter_machine_delay
    }
}

/// The SoA column entries of one LP: `(busy_until, next_event_at,
/// gvt_min)`, each `MAX` for "none". `next_event_at` is only meaningful
/// while the LP is idle; it is an absolute wall tick and therefore
/// stable until the LP's pending set or busy state next mutates — which
/// is exactly when the engine refreshes the columns.
#[inline]
fn column_values(lp: &mut Lp, now: WallTime) -> (WallTime, WallTime, SimTime) {
    let busy_until = lp.busy.map_or(WallTime::MAX, |b| b.done_at);
    let next_event_at = if lp.busy.is_some() {
        WallTime::MAX
    } else {
        lp.earliest_event_at(now).unwrap_or(WallTime::MAX)
    };
    let gvt_min = lp.gvt_contribution().unwrap_or(SimTime::MAX);
    (busy_until, next_event_at, gvt_min)
}

/// Hand-rolled std-only fixed-size bitset over `u64` words — the active
/// worklist representation. Iteration walks set bits ascending via
/// `trailing_zeros` on a local word copy; merging one bitset into
/// another is a word-OR.
#[derive(Debug, Clone, Default)]
struct FixedBitset {
    words: Vec<u64>,
}

impl FixedBitset {
    fn with_len(n: usize) -> Self {
        FixedBitset { words: vec![0; n.div_ceil(64)] }
    }

    #[inline]
    fn contains(&self, i: usize) -> bool {
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    fn insert(&mut self, i: usize) {
        self.words[i / 64] |= 1 << (i % 64);
    }
}

/// An outbox entry: `(receiver, event, sender)`. The sender id is the
/// deterministic merge key of the parallel tick.
type OutMsg = (NodeId, Event, NodeId);

/// Raw shared pointer into an engine-owned array, handed to scoped
/// workers. Safety protocol: during mutate phases every worker touches
/// only indices it owns (LPs of its contiguous index range / its
/// senders' CSR rows); during the read-only fan-out phase no `&mut`
/// exists anywhere. Phase boundaries are `Barrier`s.
struct RawSlice<T>(*mut T);

impl<T> Clone for RawSlice<T> {
    fn clone(&self) -> Self {
        RawSlice(self.0)
    }
}
impl<T> Copy for RawSlice<T> {}
unsafe impl<T: Send> Send for RawSlice<T> {}
unsafe impl<T: Send> Sync for RawSlice<T> {}

impl<T> RawSlice<T> {
    fn new(p: *mut T) -> Self {
        RawSlice(p)
    }
    /// # Safety
    /// Caller must hold exclusive logical ownership of index `i` in the
    /// current phase.
    #[inline]
    unsafe fn get(self, i: usize) -> *mut T {
        self.0.add(i)
    }
    /// # Safety
    /// Caller must guarantee no concurrent `&mut` to index `i`.
    #[inline]
    unsafe fn get_const(self, i: usize) -> *const T {
        self.0.add(i) as *const T
    }
}

/// Keeps the phase barrier releasable if a worker panics mid-phase:
/// on unwind, `Drop` performs the worker's remaining waits so its
/// peers do not deadlock — they finish their phases, the scope joins
/// everyone, and the original panic propagates.
struct BarrierGuard<'a> {
    barrier: &'a Barrier,
    remaining: u8,
}

impl<'a> BarrierGuard<'a> {
    fn new(barrier: &'a Barrier, phases: u8) -> Self {
        BarrierGuard { barrier, remaining: phases }
    }

    fn wait(&mut self) {
        self.barrier.wait();
        self.remaining -= 1;
    }
}

impl Drop for BarrierGuard<'_> {
    fn drop(&mut self) {
        for _ in 0..self.remaining {
            self.barrier.wait();
        }
    }
}

/// Per-worker results of the parallel phase 1, merged deterministically
/// (worker order for scalar sums; stable sender sort for outboxes).
#[derive(Default)]
struct WorkerOut {
    cancels: Vec<OutMsg>,
    fwds: Vec<OutMsg>,
    events_processed: u64,
    events_forwarded: u64,
    cross_machine_forwards: u64,
    rollbacks: u64,
    antimessages_sent: u64,
}

/// Everything a parallel phase-1 worker needs, bundled so the spawn
/// site stays readable. `Copy`: plain shared refs + raw pointers.
#[derive(Clone, Copy)]
struct ParCtx<'a> {
    tick: WallTime,
    graph: &'a Graph,
    part: &'a Partition,
    options: &'a SimOptions,
    cost_normal: &'a [WallTime],
    cost_rollback: &'a [WallTime],
    /// Snapshot view of the active bitset's words (not mutated during
    /// phase 1); workers iterate set bits of their word range.
    words: &'a [u64],
    lps: RawSlice<Lp>,
    ev_lp: RawSlice<u64>,
    rb_lp: RawSlice<u64>,
    xf_lp: RawSlice<u64>,
    fw_he: RawSlice<u64>,
    busy_until: RawSlice<WallTime>,
    next_event_at: RawSlice<WallTime>,
    gvt_min: RawSlice<SimTime>,
}

/// Phase-1 body executed by each scoped worker over the active LPs of
/// its contiguous word range (ascending). Sub-phases are
/// barrier-separated so that (a) `start` and `complete` touch only
/// owned LPs (and their SoA column slots), (b) the fan-out pass reads a
/// globally quiescent LP array (`seen` was last written in the start
/// phase), and (c) `retire` again touches only owned LPs — making the
/// result independent of worker interleaving and identical to the
/// sequential tick.
fn worker_phase1(ctx: ParCtx<'_>, range: (usize, usize), barrier: &Barrier) -> WorkerOut {
    let ParCtx {
        tick,
        graph,
        part,
        options,
        cost_normal,
        cost_rollback,
        words,
        lps,
        ev_lp,
        rb_lp,
        xf_lp,
        fw_he,
        busy_until,
        next_event_at,
        gvt_min,
    } = ctx;
    let mut out = WorkerOut::default();
    let mut sync = BarrierGuard::new(barrier, 3);
    // Start phase: idle LPs select + start (own-LP mutations only).
    for wi in range.0..range.1 {
        let mut w = words[wi];
        while w != 0 {
            let i = wi * 64 + w.trailing_zeros() as usize;
            w &= w - 1;
            let lp = unsafe { &mut *lps.get(i) };
            if lp.busy.is_some() {
                continue;
            }
            let machine = part.machine_of(i);
            let cr = cost_rollback[machine];
            let cn = cost_normal[machine];
            let outcome = lp.start_next(
                tick,
                |kind| match kind {
                    EventKind::Rollback => cr,
                    _ => cn,
                },
                options.inter_machine_delay,
            );
            match outcome {
                StartOutcome::Nothing => {}
                StartOutcome::Started { rolled_back, cancellations }
                | StartOutcome::RolledBack { rolled_back, cancellations } => {
                    if rolled_back > 0 {
                        unsafe { *rb_lp.get(i) += 1 };
                        out.rollbacks += 1;
                    }
                    out.antimessages_sent += cancellations.len() as u64;
                    for (nb, ev) in cancellations {
                        let mut ev = ev;
                        ev.tick = transfer_delay(part, options, i, nb);
                        out.cancels.push((nb, ev, i));
                    }
                    let (b, n, g) = column_values(lp, tick);
                    unsafe {
                        *busy_until.get(i) = b;
                        *next_event_at.get(i) = n;
                        *gvt_min.get(i) = g;
                    }
                }
            }
        }
    }
    sync.wait();
    // Complete phase: pop finished busy events (own-LP mutations only).
    let mut completed = Vec::new();
    for wi in range.0..range.1 {
        let mut w = words[wi];
        while w != 0 {
            let i = wi * 64 + w.trailing_zeros() as usize;
            w &= w - 1;
            let lp = unsafe { &mut *lps.get(i) };
            if let Some(done) = lp.complete_busy(tick) {
                completed.push((i, done));
                let (b, n, g) = column_values(lp, tick);
                unsafe {
                    *busy_until.get(i) = b;
                    *next_event_at.get(i) = n;
                    *gvt_min.get(i) = g;
                }
            }
        }
    }
    sync.wait();
    // Fan-out phase: read-only over the LP array; writes go to local
    // buffers and this worker's own slots of the epoch arrays. Forward
    // lists accumulate in one per-worker buffer, recorded as
    // `(off, len)` spans — no per-event allocation.
    let mut fwd_buf: Vec<NodeId> = Vec::new();
    let mut retires: Vec<(NodeId, Event, usize, usize)> = Vec::new();
    for &(i, done) in &completed {
        unsafe { *ev_lp.get(i) += 1 };
        out.events_processed += 1;
        if done.kind == EventKind::Rollback {
            // Anti-message consumed; nothing retires to history.
            continue;
        }
        let off = fwd_buf.len();
        if done.count > 0 {
            let machine = part.machine_of(i);
            let row = graph.row_offset(i);
            for (slot, &nb) in graph.neighbors(i).iter().enumerate() {
                let nb_seen = unsafe { (*lps.get_const(nb)).has_seen(done.thread) };
                if nb_seen {
                    continue;
                }
                let delay = transfer_delay(part, options, i, nb);
                out.fwds.push((nb, done.forwarded(options.hop_latency, delay), i));
                fwd_buf.push(nb);
                out.events_forwarded += 1;
                unsafe { *fw_he.get(row + slot) += 1 };
                if part.machine_of(nb) != machine {
                    out.cross_machine_forwards += 1;
                    unsafe { *xf_lp.get(i) += 1 };
                }
            }
        }
        retires.push((i, done, off, fwd_buf.len() - off));
    }
    sync.wait();
    // Retire phase: record completions into own history.
    for (i, done, off, len) in retires {
        let lp = unsafe { &mut *lps.get(i) };
        lp.retire(done, &fwd_buf[off..off + len]);
    }
    out
}

/// The engine.
pub struct SimEngine<'g> {
    graph: &'g Graph,
    machines: MachineConfig,
    part: Partition,
    lps: Vec<Lp>,
    options: SimOptions,
    stats: SimStats,
    gvt: SimTime,
    /// Injections sorted descending by tick (pop from the back).
    injections: Vec<Injection>,
    /// `inj_prefix_min[i]` = min event timestamp over `injections[0..=i]`
    /// — with back-pops, the minimum over the remaining (undelivered)
    /// injections is `inj_prefix_min[len - 1]`, O(1) per GVT update.
    inj_prefix_min: Vec<SimTime>,
    /// Machine-load traces (avg queue length per resident LP), Figs 9/10.
    load_traces: Vec<Trace>,
    /// Activity window since the last `take_epoch_counters` harvest.
    epoch: EpochCounters,
    /// Active worklist bitset: LPs that are busy or hold pending
    /// events. Everything else is skipped by every per-tick phase.
    active: FixedBitset,
    /// LPs activated during the current tick (disjoint from `active` by
    /// the `activate` guard), word-OR-merged at phase edges.
    newly_active: FixedBitset,
    active_count: usize,
    newly_count: usize,
    /// SoA columns indexed by `NodeId` (see [`column_values`]): phase-1
    /// gating, tick fast-forward and GVT stream these contiguous arrays
    /// instead of touching `Lp` structs.
    busy_until: Vec<WallTime>,
    next_event_at: Vec<WallTime>,
    gvt_min: Vec<SimTime>,
    /// Per-machine occupancy costs, rebuilt when the assignment changes.
    cost_normal: Vec<WallTime>,
    cost_rollback: Vec<WallTime>,
    /// Upper bound on injected thread ids; LPs pre-size their dense
    /// per-thread structures to this on first activation, keeping the
    /// steady-state tick loop allocation-free.
    thread_bound: usize,
    /// Persistent forward-list scratch of the sequential fan-out (the
    /// arena span is copied out of it by `Lp::retire`) — no per-event
    /// `Vec` allocation on the send path.
    fwd_scratch: Vec<NodeId>,
    /// Round-robin cursor of the background fossil sweep over idle LPs
    /// (bounds history retained by LPs that never reactivate).
    fossil_cursor: usize,
    /// Scratch buffers for messages produced within a tick: straggler /
    /// cascade cancellations, then completed-event forwards. Delivery
    /// order is (phase, sender, sender-push-order) — identical for the
    /// sequential and parallel paths.
    outbox_cancel: Vec<OutMsg>,
    outbox_fwd: Vec<OutMsg>,
}

impl<'g> SimEngine<'g> {
    pub fn new(
        graph: &'g Graph,
        machines: MachineConfig,
        part: Partition,
        options: SimOptions,
        mut injections: Vec<Injection>,
    ) -> Self {
        assert_eq!(part.node_count(), graph.node_count());
        assert_eq!(part.machine_count(), machines.count());
        injections.sort_by_key(|inj| std::cmp::Reverse(inj.at_tick));
        let mut inj_prefix_min = Vec::with_capacity(injections.len());
        let mut m = SimTime::MAX;
        for inj in &injections {
            m = m.min(inj.event.time);
            inj_prefix_min.push(m);
        }
        let thread_bound =
            injections.iter().map(|inj| inj.event.thread + 1).max().unwrap_or(0) as usize;
        let load_traces = (0..machines.count())
            .map(|k| Trace::new(format!("machine{k}")))
            .collect();
        let n = graph.node_count();
        let mut engine = SimEngine {
            graph,
            lps: vec![Lp::default(); n],
            machines,
            part,
            options,
            stats: SimStats::default(),
            gvt: 0,
            injections,
            inj_prefix_min,
            load_traces,
            epoch: EpochCounters::for_graph(graph),
            active: FixedBitset::with_len(n),
            newly_active: FixedBitset::with_len(n),
            active_count: 0,
            newly_count: 0,
            busy_until: vec![WallTime::MAX; n],
            next_event_at: vec![WallTime::MAX; n],
            gvt_min: vec![SimTime::MAX; n],
            cost_normal: Vec::new(),
            cost_rollback: Vec::new(),
            thread_bound,
            fwd_scratch: Vec::new(),
            fossil_cursor: 0,
            outbox_cancel: Vec::new(),
            outbox_fwd: Vec::new(),
        };
        engine.rebuild_cost_cache();
        engine
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    pub fn partition(&self) -> &Partition {
        &self.part
    }

    pub fn graph(&self) -> &Graph {
        self.graph
    }

    pub fn lps(&self) -> &[Lp] {
        &self.lps
    }

    pub fn gvt(&self) -> SimTime {
        self.gvt
    }

    pub fn load_traces(&self) -> &[Trace] {
        &self.load_traces
    }

    /// Activity accumulated since the last [`Self::take_epoch_counters`]
    /// harvest (or engine construction).
    pub fn epoch_counters(&self) -> &EpochCounters {
        &self.epoch
    }

    /// Harvest the per-epoch activity counters, resetting the window —
    /// the measurement hook of the closed rebalancing loop (§6.1).
    pub fn take_epoch_counters(&mut self) -> EpochCounters {
        let fresh = EpochCounters::for_graph(self.graph);
        std::mem::replace(&mut self.epoch, fresh)
    }

    /// Replace the LP-to-machine assignment (the dynamic-refinement hook;
    /// event transfer semantics change immediately, matching the paper's
    /// model where migration cost is ignored).
    pub fn set_partition(&mut self, part: Partition) {
        assert_eq!(part.node_count(), self.graph.node_count());
        self.part = part;
        self.rebuild_cost_cache();
    }

    /// Recompute the per-machine occupancy-cost columns (resident
    /// counts or speeds changed). Clear + extend reuses capacity.
    fn rebuild_cost_cache(&mut self) {
        self.cost_normal.clear();
        self.cost_rollback.clear();
        for k in 0..self.machines.count() {
            self.cost_normal.push(occupancy_cost(
                &self.part,
                &self.machines,
                &self.options,
                k,
                EventKind::ProcessForward,
            ));
            self.cost_rollback.push(occupancy_cost(
                &self.part,
                &self.machines,
                &self.options,
                k,
                EventKind::Rollback,
            ));
        }
    }

    fn transfer_delay(&self, from: NodeId, to: NodeId) -> WallTime {
        transfer_delay(&self.part, &self.options, from, to)
    }

    /// Refresh LP `i`'s SoA column entries after a mutation of its
    /// pending set or busy state. Retiring to history and fossil
    /// collection do not change the columns and need no refresh.
    #[inline]
    fn refresh_columns(&mut self, i: NodeId, now: WallTime) {
        let (b, n, g) = column_values(&mut self.lps[i], now);
        self.busy_until[i] = b;
        self.next_event_at[i] = n;
        self.gvt_min[i] = g;
    }

    /// Mark an LP active, catching up its deferred fossil collection
    /// first (GVT is monotone, so collecting late removes exactly the
    /// entries per-tick collection would have removed) and pre-sizing
    /// its dense per-thread structures once.
    fn activate(&mut self, i: NodeId) {
        if !self.active.contains(i) && !self.newly_active.contains(i) {
            self.lps[i].fossil_collect(self.gvt);
            self.lps[i].reserve_threads(self.thread_bound);
            self.newly_active.insert(i);
            self.newly_count += 1;
        }
    }

    /// Merge LPs activated since the last merge into the active bitset:
    /// a word-OR per 64 LPs. `activate` guarantees the two bitsets are
    /// disjoint, so the count is a plain add.
    fn merge_newly_active(&mut self) {
        if self.newly_count == 0 {
            return;
        }
        for (a, n) in self.active.words.iter_mut().zip(self.newly_active.words.iter_mut()) {
            *a |= *n;
            *n = 0;
        }
        self.active_count += self.newly_count;
        self.newly_count = 0;
    }

    /// Drop drained LPs from the worklist: per word, build a clear mask
    /// of idle-and-empty LPs and apply it in one store.
    fn sweep_inactive(&mut self) {
        for wi in 0..self.active.words.len() {
            let mut w = self.active.words[wi];
            let mut clear = 0u64;
            while w != 0 {
                let b = w.trailing_zeros();
                w &= w - 1;
                if self.lps[wi * 64 + b as usize].idle_and_empty() {
                    clear |= 1 << b;
                }
            }
            if clear != 0 {
                self.active.words[wi] &= !clear;
                self.active_count -= clear.count_ones() as usize;
            }
        }
    }

    /// Deliver any injections scheduled at `tick` (no duplicate-drop
    /// check: injections are fresh threads by construction).
    fn deliver_injections(&mut self, tick: WallTime) {
        while let Some(inj) = self.injections.last().copied() {
            if inj.at_tick > tick {
                break;
            }
            self.injections.pop();
            self.activate(inj.lp);
            self.lps[inj.lp].receive(inj.event, tick);
            self.refresh_columns(inj.lp, tick);
        }
    }

    /// Minimum event timestamp over the undelivered injections, O(1).
    fn injections_time_min(&self) -> Option<SimTime> {
        let len = self.injections.len();
        if len > 0 {
            Some(self.inj_prefix_min[len - 1])
        } else {
            None
        }
    }

    /// Compute GVT: minimum over the active LPs' contributions (busy
    /// event timestamps and pending minima, streamed from the `gvt_min`
    /// column) and the undelivered injections (Fig. 6 / Table III
    /// `global-time`). O(active).
    fn compute_gvt(&self) -> SimTime {
        let mut gvt = SimTime::MAX;
        for (wi, &word) in self.active.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                let i = wi * 64 + w.trailing_zeros() as usize;
                w &= w - 1;
                gvt = gvt.min(self.gvt_min[i]);
            }
        }
        if let Some(t) = self.injections_time_min() {
            gvt = gvt.min(t);
        }
        if gvt == SimTime::MAX {
            // Drained: GVT is the max local time (hit once, at drain).
            self.lps.iter().map(|l| l.local_time).max().unwrap_or(0)
        } else {
            gvt
        }
    }

    /// Record machine load (mean queue length per resident LP, §6.1) at
    /// the current tick. O(active + K): idle LPs have empty queues.
    fn record_loads(&mut self) {
        let k = self.machines.count();
        let mut sums = vec![0.0f64; k];
        for (wi, &word) in self.active.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                let i = wi * 64 + w.trailing_zeros() as usize;
                w &= w - 1;
                sums[self.part.machine_of(i)] += self.lps[i].queue_len() as f64;
            }
        }
        for m in 0..k {
            let cnt = self.part.count(m).max(1) as f64;
            self.load_traces[m].push(self.stats.ticks as f64, sums[m] / cnt);
        }
    }

    /// All work drained (and no injections outstanding)?
    pub fn drained(&self) -> bool {
        self.injections.is_empty() && self.active_count == 0 && self.newly_count == 0
    }

    /// Wall ticks that can be skipped in one jump because they are
    /// provably no-ops: every active LP is either busy with completion
    /// strictly in the future or waiting on transfer delays, and no
    /// injection, trace point, or external boundary lands inside the
    /// window. Streams the SoA columns — no `Lp` struct is touched.
    /// Returns `None` when the current tick must be executed.
    fn fast_forward(&self, tick: WallTime, tick_limit: WallTime) -> Option<WallTime> {
        let limit = tick_limit.min(self.options.max_ticks);
        let mut dt = limit.saturating_sub(tick);
        if dt == 0 {
            return None;
        }
        if self.options.trace_every > 0 {
            if tick % self.options.trace_every == 0 {
                return None; // this tick records a trace point
            }
            dt = dt.min(self.options.trace_every - tick % self.options.trace_every);
        }
        if let Some(inj) = self.injections.last() {
            debug_assert!(inj.at_tick > tick, "due injection not delivered");
            dt = dt.min(inj.at_tick - tick);
        }
        for (wi, &word) in self.active.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                let i = wi * 64 + w.trailing_zeros() as usize;
                w &= w - 1;
                let bu = self.busy_until[i];
                if bu != WallTime::MAX {
                    if bu <= tick {
                        return None; // completes this tick
                    }
                    dt = dt.min(bu - tick);
                } else {
                    let ne = self.next_event_at[i];
                    if ne <= tick {
                        return None; // ready event
                    }
                    if ne != WallTime::MAX {
                        dt = dt.min(ne - tick);
                    }
                }
            }
        }
        // Every reduction above yields >= 1 (guards return None first);
        // the bound is defensive.
        (dt >= 1).then_some(dt)
    }

    /// Sequential phase 1: starts (with straggler / cascade
    /// cancellations), then completions with forward fan-out. The two
    /// passes mirror the parallel sub-phases: all `seen` mutations
    /// happen in the start pass, so the fan-out pass observes the same
    /// neighbor state in any LP order. Gating reads the `busy_until`
    /// column; phase 1 never activates or deactivates LPs, so iterating
    /// local copies of the bitset words is stable.
    fn phase1_sequential(&mut self, tick: WallTime) {
        for wi in 0..self.active.words.len() {
            let mut w = self.active.words[wi];
            while w != 0 {
                let i = wi * 64 + w.trailing_zeros() as usize;
                w &= w - 1;
                if self.busy_until[i] != WallTime::MAX {
                    debug_assert!(self.lps[i].busy.is_some(), "stale busy_until column");
                    continue;
                }
                debug_assert!(self.lps[i].busy.is_none(), "stale busy_until column");
                let machine = self.part.machine_of(i);
                let cr = self.cost_rollback[machine];
                let cn = self.cost_normal[machine];
                let outcome = self.lps[i].start_next(
                    tick,
                    |kind| match kind {
                        EventKind::Rollback => cr,
                        _ => cn,
                    },
                    self.options.inter_machine_delay,
                );
                match outcome {
                    StartOutcome::Nothing => {}
                    outcome => {
                        self.note_start_outcome(i, outcome);
                        self.refresh_columns(i, tick);
                    }
                }
            }
        }
        for wi in 0..self.active.words.len() {
            let mut w = self.active.words[wi];
            while w != 0 {
                let i = wi * 64 + w.trailing_zeros() as usize;
                w &= w - 1;
                if self.busy_until[i] > tick {
                    continue; // idle (MAX) or still busy past this tick
                }
                if let Some(done) = self.lps[i].complete_busy(tick) {
                    self.note_completion(i, done);
                    self.refresh_columns(i, tick);
                }
            }
        }
    }

    fn note_start_outcome(&mut self, i: NodeId, outcome: StartOutcome) {
        match outcome {
            StartOutcome::Nothing => {}
            StartOutcome::Started { rolled_back, cancellations }
            | StartOutcome::RolledBack { rolled_back, cancellations } => {
                if rolled_back > 0 {
                    self.epoch.rollbacks_by_lp[i] += 1;
                    self.stats.rollbacks += 1;
                }
                self.stats.antimessages_sent += cancellations.len() as u64;
                for (nb, ev) in cancellations {
                    // Anti-message delay follows the link type.
                    let mut ev = ev;
                    ev.tick = self.transfer_delay(i, nb);
                    self.outbox_cancel.push((nb, ev, i));
                }
            }
        }
    }

    fn note_completion(&mut self, i: NodeId, done: Event) {
        self.stats.events_processed += 1;
        self.epoch.events_by_lp[i] += 1;
        if done.kind == EventKind::Rollback {
            // Anti-message consumed; nothing retires to history.
            return;
        }
        let graph = self.graph;
        self.fwd_scratch.clear();
        if done.count > 0 {
            let machine = self.part.machine_of(i);
            let row = graph.row_offset(i);
            for (slot, &nb) in graph.neighbors(i).iter().enumerate() {
                if self.lps[nb].has_seen(done.thread) {
                    continue;
                }
                let delay = self.transfer_delay(i, nb);
                self.outbox_fwd.push((nb, done.forwarded(self.options.hop_latency, delay), i));
                self.fwd_scratch.push(nb);
                self.stats.events_forwarded += 1;
                self.epoch.forwards_by_half_edge[row + slot] += 1;
                if self.part.machine_of(nb) != machine {
                    self.stats.cross_machine_forwards += 1;
                    self.epoch.cross_forwards_by_lp[i] += 1;
                }
            }
        }
        self.lps[i].retire(done, &self.fwd_scratch);
    }

    /// Parallel phase 1: the active bitset's words are split into
    /// `workers` contiguous ranges balanced by popcount; each scoped
    /// worker owns the LPs (and SoA column slots) of its range and runs
    /// the barrier-separated sub-phases of [`worker_phase1`]. Scalar
    /// stats merge in worker order; outboxes merge by stable sender
    /// sort — both reproduce the sequential tick exactly.
    fn phase1_parallel(&mut self, tick: WallTime, workers: usize) {
        // Split word indices by cumulative popcount. Empty trailing
        // ranges pad to exactly `workers` entries: every spawned worker
        // must participate in the barriers.
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(workers);
        let nwords = self.active.words.len();
        let target = self.active_count.div_ceil(workers).max(1);
        let mut start = 0usize;
        let mut acc = 0usize;
        for wi in 0..nwords {
            acc += self.active.words[wi].count_ones() as usize;
            if acc >= target && ranges.len() + 1 < workers {
                ranges.push((start, wi + 1));
                start = wi + 1;
                acc = 0;
            }
        }
        ranges.push((start, nwords));
        while ranges.len() < workers {
            ranges.push((nwords, nwords));
        }

        let lps = RawSlice::new(self.lps.as_mut_ptr());
        let ev_lp = RawSlice::new(self.epoch.events_by_lp.as_mut_ptr());
        let rb_lp = RawSlice::new(self.epoch.rollbacks_by_lp.as_mut_ptr());
        let xf_lp = RawSlice::new(self.epoch.cross_forwards_by_lp.as_mut_ptr());
        let fw_he = RawSlice::new(self.epoch.forwards_by_half_edge.as_mut_ptr());
        let busy_until = RawSlice::new(self.busy_until.as_mut_ptr());
        let next_event_at = RawSlice::new(self.next_event_at.as_mut_ptr());
        let gvt_min = RawSlice::new(self.gvt_min.as_mut_ptr());
        let ctx = ParCtx {
            tick,
            graph: self.graph,
            part: &self.part,
            options: &self.options,
            cost_normal: &self.cost_normal,
            cost_rollback: &self.cost_rollback,
            words: &self.active.words,
            lps,
            ev_lp,
            rb_lp,
            xf_lp,
            fw_he,
            busy_until,
            next_event_at,
            gvt_min,
        };
        let barrier = Barrier::new(workers);
        let mut outs: Vec<WorkerOut> = Vec::with_capacity(workers);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(workers);
            for &range in &ranges {
                let barrier = &barrier;
                handles.push(s.spawn(move || worker_phase1(ctx, range, barrier)));
            }
            for h in handles {
                outs.push(h.join().expect("sim worker panicked"));
            }
        });
        for out in &mut outs {
            self.stats.events_processed += out.events_processed;
            self.stats.events_forwarded += out.events_forwarded;
            self.stats.cross_machine_forwards += out.cross_machine_forwards;
            self.stats.rollbacks += out.rollbacks;
            self.stats.antimessages_sent += out.antimessages_sent;
            self.outbox_cancel.append(&mut out.cancels);
            self.outbox_fwd.append(&mut out.fwds);
        }
        // Stable sender sort == sequential emission order (each sender's
        // messages were pushed in its own neighbor order).
        self.outbox_cancel.sort_by_key(|&(_, _, from)| from);
        self.outbox_fwd.sort_by_key(|&(_, _, from)| from);
    }

    /// Deliver buffered messages: cancellations first, then forwards,
    /// each in ascending sender order — the canonical delivery order.
    fn deliver_outboxes(&mut self, tick: WallTime) {
        let mut cancels = std::mem::take(&mut self.outbox_cancel);
        for &(nb, ev, _) in &cancels {
            self.deliver_one(nb, ev, tick);
        }
        cancels.clear();
        self.outbox_cancel = cancels;
        let mut fwds = std::mem::take(&mut self.outbox_fwd);
        for &(nb, ev, _) in &fwds {
            self.deliver_one(nb, ev, tick);
        }
        fwds.clear();
        self.outbox_fwd = fwds;
    }

    fn deliver_one(&mut self, nb: NodeId, ev: Event, tick: WallTime) {
        // Receivers that already saw the thread (race within the tick)
        // drop duplicate forwards.
        if ev.kind != EventKind::Rollback && self.lps[nb].has_seen(ev.thread) {
            return;
        }
        self.activate(nb);
        self.lps[nb].receive(ev, tick);
        self.refresh_columns(nb, tick);
    }

    /// Execute one wall-clock step (Fig. 6 body), never advancing past
    /// `tick_limit` in a fast-forward jump — drivers pass their next
    /// epoch / refinement boundary so closed-loop schedules are exact.
    /// Returns `false` once drained.
    pub fn step_bounded(&mut self, tick_limit: WallTime) -> bool {
        if self.drained() {
            return false;
        }
        let tick = self.stats.ticks;
        self.deliver_injections(tick);
        self.merge_newly_active();

        if let Some(dt) = self.fast_forward(tick, tick_limit) {
            self.stats.ticks += dt;
            self.epoch.ticks += dt;
            return true;
        }

        // Phase 1: starts + completions, producing the outboxes.
        let workers = if self.options.parallelism == 0 {
            1
        } else {
            self.options.parallelism.min(self.machines.count())
        };
        if workers > 1 && self.active_count >= self.options.parallel_min_active {
            self.phase1_parallel(tick, workers);
        } else {
            self.phase1_sequential(tick);
        }

        // Phase 2: deliver buffered messages.
        self.deliver_outboxes(tick);
        self.merge_newly_active();

        // Phase 3: GVT advances, fossils collect, worklist compacts.
        self.gvt = self.compute_gvt();
        for wi in 0..self.active.words.len() {
            let mut w = self.active.words[wi];
            while w != 0 {
                let i = wi * 64 + w.trailing_zeros() as usize;
                w &= w - 1;
                self.lps[i].fossil_collect(self.gvt);
            }
        }
        self.sweep_inactive();

        // Background fossil sweep: a few idle LPs per executed tick, so
        // history retained by LPs that drained and never reactivate is
        // bounded. GVT is monotone, so late collection removes exactly
        // what per-tick collection would have — observable state is
        // unchanged.
        const FOSSIL_SWEEP_PER_TICK: usize = 64;
        let n = self.lps.len();
        for _ in 0..FOSSIL_SWEEP_PER_TICK.min(n) {
            let i = self.fossil_cursor;
            self.fossil_cursor = (self.fossil_cursor + 1) % n;
            if !self.active.contains(i)
                && !self.newly_active.contains(i)
                && !self.lps[i].history_is_empty()
            {
                self.lps[i].fossil_collect(self.gvt);
            }
        }

        self.stats.ticks += 1;
        self.epoch.ticks += 1;
        if self.options.trace_every > 0 && tick % self.options.trace_every == 0 {
            self.record_loads();
        }
        true
    }

    /// Execute one wall-clock step (fast-forward bounded only by
    /// `max_ticks`). Returns `false` once drained.
    pub fn step(&mut self) -> bool {
        self.step_bounded(self.options.max_ticks)
    }

    /// Capture the full resumable engine state in canonical order
    /// (`sim::snapshot`). Must be called between steps (outboxes empty —
    /// always true at an epoch boundary); the index layout (slot slab,
    /// heap order, bitset worklist, arena offsets, SoA columns) is *not*
    /// captured: it is re-derived deterministically on restore, which is
    /// what makes save→load→save byte-identical across layouts.
    pub fn capture_state(&self) -> crate::sim::snapshot::EngineState {
        assert!(
            self.outbox_cancel.is_empty() && self.outbox_fwd.is_empty(),
            "capture_state mid-tick: outboxes not drained"
        );
        let lps = self
            .lps
            .iter()
            .map(|lp| {
                let mut pending: Vec<(Event, WallTime)> = lp.pending_with_ready_at().collect();
                pending.sort_by_key(|&(e, r)| crate::sim::snapshot::pending_sort_key(&e, r));
                // Bitset iteration is already ascending — the canonical
                // snapshot order.
                let seen: Vec<_> = lp.seen_threads().collect();
                crate::sim::snapshot::LpState {
                    pending,
                    seen,
                    local_time: lp.local_time,
                    busy: lp.busy.map(|b| (b.event, b.done_at)),
                    history: lp.history_entries().map(|(e, f)| (e, f.to_vec())).collect(),
                    rollbacks: lp.rollbacks,
                }
            })
            .collect();
        crate::sim::snapshot::EngineState {
            stats: self.stats.clone(),
            gvt: self.gvt,
            assignment: self.part.assignment().to_vec(),
            injections: self.injections.clone(),
            epoch: self.epoch.clone(),
            fossil_cursor: self.fossil_cursor as u64,
            lps,
        }
    }

    /// Rebuild an engine from a captured state. The graph must be the
    /// one the state was captured against (weights may differ — they do
    /// not enter engine semantics); `machines` may differ from the
    /// capture-time fleet (elastic restore re-homes the assignment
    /// first). Load traces are observational and restart empty.
    pub fn from_state(
        graph: &'g Graph,
        machines: MachineConfig,
        options: SimOptions,
        state: crate::sim::snapshot::EngineState,
    ) -> Self {
        assert_eq!(state.lps.len(), graph.node_count(), "snapshot LP count != graph");
        assert_eq!(state.assignment.len(), graph.node_count());
        assert_eq!(state.epoch.events_by_lp.len(), graph.node_count());
        assert_eq!(state.epoch.forwards_by_half_edge.len(), graph.half_edge_count());
        let part = Partition::from_assignment(graph, machines.count(), state.assignment);
        let mut engine = SimEngine::new(graph, machines, part, options, state.injections);
        engine.stats = state.stats;
        engine.gvt = state.gvt;
        engine.epoch = state.epoch;
        engine.fossil_cursor = (state.fossil_cursor as usize) % graph.node_count().max(1);
        let now = engine.stats.ticks;
        for (i, lp_state) in state.lps.into_iter().enumerate() {
            let lp = &mut engine.lps[i];
            lp.restore_pending(lp_state.pending, now);
            for t in lp_state.seen {
                lp.mark_seen(t);
            }
            lp.local_time = lp_state.local_time;
            lp.busy = lp_state.busy.map(|(event, done_at)| crate::sim::lp::Busy { event, done_at });
            lp.restore_history(lp_state.history);
            lp.rollbacks = lp_state.rollbacks;
        }
        // Re-derive the active bitset (exactly the LPs that are busy or
        // hold pending events) and the SoA columns.
        for i in 0..engine.lps.len() {
            if !engine.lps[i].idle_and_empty() {
                engine.lps[i].reserve_threads(engine.thread_bound);
                engine.active.insert(i);
                engine.active_count += 1;
            }
            engine.refresh_columns(i, now);
        }
        engine
    }

    /// Run until drained or `max_ticks`. Returns final stats.
    pub fn run_to_completion(&mut self) -> SimStats {
        while self.stats.ticks < self.options.max_ticks {
            if !self.step() {
                break;
            }
        }
        if !self.drained() {
            self.stats.truncated = true;
        }
        self.stats.clone()
    }
}

//! The tick-driven optimistic simulation engine (paper Fig. 6).
//!
//! Owns all LPs, the LP-to-machine assignment, and the wall-clock loop:
//!
//! 1. injections scheduled for this tick arrive,
//! 2. idle LPs select + start their lowest-timestamped ready event
//!    (stragglers roll back, anti-messages cascade),
//! 3. busy LPs complete; completed forwarding events flood to unseen
//!    neighbors (cross-machine forwards pay the `event-tick` delay),
//! 4. buffered messages deliver, GVT updates, fossils collect.
//!
//! Processing an event occupies the LP for
//! `ceil(resident_LPs × base_time / (w_k · K))` ticks — machine speed
//! inversely proportional to resident LP count (§6.1), generalized to
//! heterogeneous speeds `w_k`.
//!
//! # Hot-path architecture (DESIGN.md §3, §11)
//!
//! Per-tick cost scales with *activity*, not graph size, and the data
//! layout is cache-conscious struct-of-arrays:
//!
//! * the **active-LP worklist** is a fixed `u64`-word bitset
//!   (`FixedBitset`): membership is one bit test, the per-tick merge
//!   of newly activated LPs is a word-OR, and every phase walks set
//!   bits in ascending order (`trailing_zeros` + clear-lowest-bit).
//!   Idle-and-empty LPs cost zero. Fossil collection on idle LPs is
//!   deferred and caught up when a message reactivates them (GVT is
//!   monotone, so late collection removes the same entries);
//! * **SoA scalar columns** indexed by `NodeId` shadow the per-LP hot
//!   scalars: `busy_until` (absolute completion tick, `MAX` = idle),
//!   `next_event_at` (earliest processable tick when idle, `MAX` =
//!   none) and `gvt_min` (the LP's GVT contribution, `MAX` = none).
//!   Tick fast-forward and GVT computation stream these contiguous
//!   columns instead of chasing `Lp` structs; every LP mutation site
//!   refreshes the mutated LP's column entries (`column_values`);
//! * **occupancy costs are cached per machine** (`cost_normal`,
//!   `cost_rollback`), rebuilt only when the assignment changes —
//!   the start phase does two array loads instead of float math;
//! * **incremental GVT**: the undelivered-injection minimum comes from
//!   a prefix-min array computed once at construction — per-tick GVT
//!   is O(active), never O(N + injections);
//! * **tick fast-forward**: when every active LP is counting down busy
//!   time or transfer delays and no injection is due, the engine jumps
//!   `Δ = min(remaining)` wall ticks in one step. Stats, traces and
//!   epoch counters advance by Δ; results are bit-identical to stepping
//!   the Δ no-op ticks one by one (nothing starts, completes, arrives,
//!   or moves GVT inside the window by construction of Δ);
//! * **parallel execution by contiguous index ranges**
//!   (`SimOptions::parallelism`): the active bitset's words are split
//!   into per-worker ranges balanced by popcount, so each scoped
//!   worker owns a contiguous slice of the LP array (and of the SoA
//!   columns) and streams it in barrier-separated sub-phases
//!   (start | complete | fan-out | retire). Per-worker outboxes merge
//!   in deterministic sender order (stable sort by source LP), making
//!   parallel runs **bit-identical** to sequential ones — the §5
//!   determinism contract extends to `parallelism > 1` (see DESIGN.md
//!   §5 and the equivalence suite).

use crate::graph::{Graph, NodeId};
use crate::sim::event::{Event, SimTime, WallTime};

mod core;

pub use self::core::SimEngine;

/// Static engine options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Base process time of a normal event (wall ticks).
    pub base_process_time: WallTime,
    /// Base process time of a rollback event.
    pub rollback_process_time: WallTime,
    /// Wall-clock delay of a cross-machine event transfer.
    pub inter_machine_delay: WallTime,
    /// Wall-clock delay of an intra-machine event transfer.
    pub intra_machine_delay: WallTime,
    /// Simulation-time latency per flood hop.
    pub hop_latency: SimTime,
    /// Record machine-load traces every this many ticks (0 = never).
    pub trace_every: WallTime,
    /// Safety cap on wall ticks.
    pub max_ticks: WallTime,
    /// Worker threads for per-machine tick execution (0/1 = sequential).
    /// Any value produces bit-identical results; see DESIGN.md §5.
    pub parallelism: usize,
    /// Minimum active-LP count before a tick is worth parallelizing:
    /// the parallel path spawns scoped workers per tick, so below this
    /// the spawn + barrier overhead dominates the tick's work. Purely a
    /// scheduling knob: results are identical either way.
    pub parallel_min_active: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            base_process_time: 1,
            rollback_process_time: 1,
            inter_machine_delay: 3,
            intra_machine_delay: 0,
            hop_latency: 1,
            trace_every: 0,
            max_ticks: 2_000_000,
            parallelism: 1,
            parallel_min_active: 1024,
        }
    }
}

/// Aggregate statistics of a run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Total wall-clock ticks consumed so far — the paper's headline
    /// *simulation time* metric.
    pub ticks: WallTime,
    pub events_processed: u64,
    pub events_forwarded: u64,
    pub cross_machine_forwards: u64,
    pub rollbacks: u64,
    pub antimessages_sent: u64,
    /// True if the run hit `max_ticks` before draining.
    pub truncated: bool,
}

/// A scheduled packet injection: `(wall_tick, lp, event)`.
#[derive(Debug, Clone, Copy)]
pub struct Injection {
    pub at_tick: WallTime,
    pub lp: NodeId,
    pub event: Event,
}

/// Per-LP / per-edge activity accumulated since the last harvest — the
/// measured load signals (§6.1) the closed-loop rebalancer
/// (`sim::dynamic`) feeds to its weight estimators. Global [`SimStats`]
/// counters are cumulative; these reset at every
/// [`SimEngine::take_epoch_counters`] call.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EpochCounters {
    /// Wall ticks covered by this window.
    pub ticks: WallTime,
    /// Events completed per LP (including rollback processing).
    pub events_by_lp: Vec<u64>,
    /// Rollback episodes per LP.
    pub rollbacks_by_lp: Vec<u64>,
    /// Cross-machine forwards originated per LP.
    pub cross_forwards_by_lp: Vec<u64>,
    /// Forwards per directed half-edge, aligned with the graph's CSR
    /// slots (`Graph::row_offset(u) + k` = `u`'s `k`-th neighbor) — a
    /// flat add on the hot path instead of a hash lookup.
    pub forwards_by_half_edge: Vec<u64>,
}

impl EpochCounters {
    pub(crate) fn for_graph(graph: &Graph) -> Self {
        let n = graph.node_count();
        EpochCounters {
            ticks: 0,
            events_by_lp: vec![0; n],
            rollbacks_by_lp: vec![0; n],
            cross_forwards_by_lp: vec![0; n],
            forwards_by_half_edge: vec![0; graph.half_edge_count()],
        }
    }

    /// Forwards that crossed edge `{u, v}` (either direction) during
    /// the window.
    pub fn forwards_on(&self, graph: &Graph, u: NodeId, v: NodeId) -> u64 {
        let uv = graph.half_edge_index(u, v).map_or(0, |s| self.forwards_by_half_edge[s]);
        let vu = graph.half_edge_index(v, u).map_or(0, |s| self.forwards_by_half_edge[s]);
        uv + vu
    }

    /// Total events completed during the window.
    pub fn events_total(&self) -> u64 {
        self.events_by_lp.iter().sum()
    }

    /// Total rollback episodes during the window.
    pub fn rollbacks_total(&self) -> u64 {
        self.rollbacks_by_lp.iter().sum()
    }

    /// Total cross-machine forwards during the window.
    pub fn cross_forwards_total(&self) -> u64 {
        self.cross_forwards_by_lp.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::partition::{MachineConfig, Partition};

    fn line_graph(n: usize) -> Graph {
        let mut b = GraphBuilder::with_nodes(n);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1, 1.0);
        }
        b.build()
    }

    fn engine_on(
        graph: &Graph,
        k: usize,
        assignment: Vec<usize>,
        injections: Vec<Injection>,
        options: SimOptions,
    ) -> SimEngine<'_> {
        let machines = MachineConfig::homogeneous(k);
        let part = Partition::from_assignment(graph, k, assignment);
        SimEngine::new(graph, machines, part, options, injections)
    }

    #[test]
    fn single_event_drains() {
        let g = line_graph(3);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 0) }];
        let mut e = engine_on(&g, 1, vec![0, 0, 0], inj, SimOptions::default());
        let stats = e.run_to_completion();
        assert!(!stats.truncated);
        assert_eq!(stats.events_processed, 1);
        assert_eq!(stats.events_forwarded, 0);
        assert!(e.drained());
    }

    #[test]
    fn flood_covers_hop_limit() {
        // Line 0-1-2-3-4, flood from node 0 with 2 hops: reaches 0,1,2.
        let g = line_graph(5);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 2) }];
        let mut e = engine_on(&g, 1, vec![0; 5], inj, SimOptions::default());
        let stats = e.run_to_completion();
        assert!(!stats.truncated);
        assert_eq!(stats.events_processed, 3, "nodes 0,1,2 each process once");
        assert_eq!(stats.events_forwarded, 2);
        assert_eq!(stats.rollbacks, 0);
    }

    #[test]
    fn flood_branches_to_all_unseen_neighbors() {
        // Star: center 0 with 4 leaves; 1 hop floods to all leaves.
        let mut b = GraphBuilder::with_nodes(5);
        for leaf in 1..5 {
            b.add_edge(0, leaf, 1.0);
        }
        let g = b.build();
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 1) }];
        let mut e = engine_on(&g, 1, vec![0; 5], inj, SimOptions::default());
        let stats = e.run_to_completion();
        assert_eq!(stats.events_processed, 5);
        assert_eq!(stats.events_forwarded, 4);
    }

    #[test]
    fn no_duplicate_delivery_on_cycles() {
        // Triangle: flood with large hop budget must visit each LP once.
        let mut b = GraphBuilder::with_nodes(3);
        b.add_edge(0, 1, 1.0).add_edge(1, 2, 1.0).add_edge(0, 2, 1.0);
        let g = b.build();
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 10) }];
        let mut e = engine_on(&g, 1, vec![0; 3], inj, SimOptions::default());
        let stats = e.run_to_completion();
        assert_eq!(stats.events_processed, 3, "each LP exactly once");
    }

    #[test]
    fn cross_machine_forwards_counted_and_slower() {
        let g = line_graph(4);
        let inj = || vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 3) }];
        // Two residents per machine in both configs so occupancy costs
        // match and only the transfer delays differ.
        // Contiguous halves: one crossing (edge 1-2).
        let mut same = engine_on(&g, 2, vec![0, 0, 1, 1], inj(), SimOptions::default());
        let s1 = same.run_to_completion();
        assert_eq!(s1.cross_machine_forwards, 1);
        // Alternating machines: every forward crosses.
        let mut alt = engine_on(&g, 2, vec![0, 1, 0, 1], inj(), SimOptions::default());
        let s2 = alt.run_to_completion();
        assert_eq!(s2.cross_machine_forwards, 3);
        assert!(
            s2.ticks > s1.ticks,
            "cross-machine delays must slow the run: {} vs {}",
            s2.ticks,
            s1.ticks
        );
    }

    #[test]
    fn occupancy_scales_with_resident_lps() {
        // 10 LPs on one machine: each event takes 10 ticks of busy time,
        // so a single flood over a line is much slower than with 2 LPs.
        let g = line_graph(10);
        let inj = || vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 0) }];
        let mut crowded = engine_on(&g, 1, vec![0; 10], inj(), SimOptions::default());
        let c = crowded.run_to_completion();
        // The single event costs ceil(10×1/1) = 10 busy ticks.
        assert!(c.ticks >= 10, "crowded machine too fast: {} ticks", c.ticks);
    }

    #[test]
    fn straggler_causes_rollback_cross_machine() {
        // LP1 receives a fast local event chain advancing its clock, then
        // a delayed cross-machine event with an older timestamp arrives.
        let mut b = GraphBuilder::with_nodes(3);
        b.add_edge(0, 1, 1.0).add_edge(1, 2, 1.0);
        let g = b.build();
        let injections = vec![
            // Thread 1: starts at LP2 (same machine as LP1), timestamp 10,
            // floods to LP1 quickly.
            Injection { at_tick: 0, lp: 2, event: Event::injection(1, 10, 1) },
            // Thread 2: starts at LP0 (other machine), OLD timestamp 1,
            // floods to LP1 but arrives late due to inter-machine delay.
            Injection { at_tick: 0, lp: 0, event: Event::injection(2, 1, 1) },
        ];
        let opts = SimOptions { inter_machine_delay: 8, ..Default::default() };
        let mut e = engine_on(&g, 2, vec![1, 0, 0], injections, opts);
        let stats = e.run_to_completion();
        assert!(stats.rollbacks > 0, "expected a straggler rollback; stats: {stats:?}");
        assert!(!stats.truncated);
    }

    #[test]
    fn repartition_mid_run_changes_delays() {
        let g = line_graph(6);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 5) }];
        let machines = MachineConfig::homogeneous(2);
        let part = Partition::from_assignment(&g, 2, vec![0, 1, 0, 1, 0, 1]);
        let mut e = SimEngine::new(&g, machines, part, SimOptions::default(), inj);
        // After a few steps, collapse everything onto machine 0.
        for _ in 0..3 {
            e.step();
        }
        let better = Partition::from_assignment(&g, 2, vec![0; 6]);
        e.set_partition(better);
        let stats = e.run_to_completion();
        assert!(!stats.truncated);
        assert!(e.drained());
    }

    #[test]
    fn load_traces_recorded() {
        let g = line_graph(4);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 3) }];
        let opts = SimOptions { trace_every: 1, ..Default::default() };
        let mut e = engine_on(&g, 2, vec![0, 0, 1, 1], inj, opts);
        let _ = e.run_to_completion();
        assert_eq!(e.load_traces().len(), 2);
        assert!(e.load_traces()[0].len() > 0);
    }

    #[test]
    fn gvt_monotone_nondecreasing() {
        let g = line_graph(8);
        let injections: Vec<Injection> = (0..4)
            .map(|t| Injection {
                at_tick: t * 2,
                lp: (t as usize) * 2,
                event: Event::injection(t + 1, t * 5, 2),
            })
            .collect();
        let mut e =
            engine_on(&g, 2, vec![0, 0, 0, 0, 1, 1, 1, 1], injections, SimOptions::default());
        let mut last_gvt = 0;
        while e.step() {
            assert!(e.gvt() >= last_gvt, "GVT regressed: {} -> {}", last_gvt, e.gvt());
            last_gvt = e.gvt();
        }
    }

    #[test]
    fn epoch_counters_track_activity_and_reset() {
        let g = line_graph(4);
        let inj =
            vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 3) }];
        let mut e = engine_on(&g, 2, vec![0, 0, 1, 1], inj, SimOptions::default());
        let stats = e.run_to_completion();
        let c = e.epoch_counters();
        assert_eq!(c.events_total(), stats.events_processed);
        assert_eq!(c.cross_forwards_total(), stats.cross_machine_forwards);
        assert_eq!(
            c.forwards_on(&g, 0, 1) + c.forwards_on(&g, 1, 2) + c.forwards_on(&g, 2, 3),
            stats.events_forwarded
        );
        assert_eq!(c.ticks, stats.ticks);
        let taken = e.take_epoch_counters();
        assert_eq!(taken.events_total(), stats.events_processed);
        assert_eq!(e.epoch_counters().events_total(), 0);
        assert_eq!(e.epoch_counters().ticks, 0);
        assert!(e.epoch_counters().forwards_by_half_edge.iter().all(|&x| x == 0));
    }

    #[test]
    fn late_injections_arrive() {
        let g = line_graph(3);
        let injections = vec![
            Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 0) },
            Injection { at_tick: 50, lp: 2, event: Event::injection(2, 100, 0) },
        ];
        let mut e = engine_on(&g, 1, vec![0; 3], injections, SimOptions::default());
        let stats = e.run_to_completion();
        assert_eq!(stats.events_processed, 2);
        assert!(stats.ticks > 50);
    }

    #[test]
    fn fast_forward_skips_idle_gaps_in_few_steps() {
        // One event at tick 0, the next at tick 10_000: the gap must be
        // jumped, not walked — the whole run takes a handful of steps.
        let g = line_graph(3);
        let injections = vec![
            Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 0) },
            Injection { at_tick: 10_000, lp: 2, event: Event::injection(2, 9_000, 0) },
        ];
        let mut e = engine_on(&g, 1, vec![0; 3], injections, SimOptions::default());
        let mut steps = 0u64;
        while e.step() {
            steps += 1;
            assert!(steps < 100, "fast-forward failed to engage");
        }
        let stats = e.stats().clone();
        assert_eq!(stats.events_processed, 2);
        assert!(stats.ticks > 10_000);
        assert!(!e.run_to_completion().truncated);
    }

    #[test]
    fn step_bounded_respects_the_boundary() {
        let g = line_graph(3);
        let injections = vec![
            Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 0) },
            Injection { at_tick: 5_000, lp: 2, event: Event::injection(2, 4_000, 0) },
        ];
        let mut e = engine_on(&g, 1, vec![0; 3], injections, SimOptions::default());
        // Run with a boundary at 1_000: no jump may cross it.
        while e.stats().ticks < 1_000 && e.step_bounded(1_000) {}
        assert_eq!(e.stats().ticks, 1_000, "jump overshot the boundary");
        assert!(!e.drained());
    }

    #[test]
    fn capture_restore_mid_run_continues_bit_identically() {
        let g = line_graph(10);
        let injections: Vec<Injection> = (0..6)
            .map(|t| Injection {
                at_tick: t * 3,
                lp: (t as usize * 2) % 10,
                event: Event::injection(t + 1, t * 7, 3),
            })
            .collect();
        let assignment: Vec<usize> = (0..10).map(|i| i % 2).collect();
        let mut uninterrupted =
            engine_on(&g, 2, assignment.clone(), injections.clone(), SimOptions::default());
        let mut live = engine_on(&g, 2, assignment, injections, SimOptions::default());
        for _ in 0..7 {
            uninterrupted.step();
            live.step();
        }
        let state = live.capture_state();
        let machines = MachineConfig::homogeneous(2);
        let mut restored = SimEngine::from_state(&g, machines, SimOptions::default(), state);
        assert_eq!(restored.stats(), live.stats());
        assert_eq!(restored.gvt(), live.gvt());
        let a = uninterrupted.run_to_completion();
        let b = restored.run_to_completion();
        assert_eq!(a, b, "restored run diverged from uninterrupted run");
        assert_eq!(uninterrupted.gvt(), restored.gvt());
        assert_eq!(uninterrupted.epoch_counters(), restored.epoch_counters());
    }

    #[test]
    fn capture_of_restored_engine_is_identical() {
        let g = line_graph(8);
        let injections: Vec<Injection> = (0..5)
            .map(|t| Injection {
                at_tick: t,
                lp: (t as usize) % 8,
                event: Event::injection(t + 1, t * 4, 2),
            })
            .collect();
        let mut e =
            engine_on(&g, 2, (0..8).map(|i| i % 2).collect(), injections, SimOptions::default());
        for _ in 0..5 {
            e.step();
        }
        let state = e.capture_state();
        let restored =
            SimEngine::from_state(&g, MachineConfig::homogeneous(2), SimOptions::default(), state);
        let again = restored.capture_state();
        let state2 = e.capture_state();
        assert_eq!(state2.stats, again.stats);
        assert_eq!(state2.gvt, again.gvt);
        assert_eq!(state2.assignment, again.assignment);
        assert_eq!(state2.fossil_cursor, again.fossil_cursor);
        assert_eq!(state2.lps.len(), again.lps.len());
        for (a, b) in state2.lps.iter().zip(again.lps.iter()) {
            assert_eq!(a.pending.len(), b.pending.len());
            for (&(ea, ra), &(eb, rb)) in a.pending.iter().zip(b.pending.iter()) {
                assert_eq!(
                    (ea.thread, ea.time, ea.kind, ea.count, ra),
                    (eb.thread, eb.time, eb.kind, eb.count, rb)
                );
            }
            assert_eq!(a.seen, b.seen);
            assert_eq!(a.local_time, b.local_time);
            assert_eq!(a.rollbacks, b.rollbacks);
        }
    }

    #[test]
    fn parallel_engine_matches_sequential() {
        let g = line_graph(12);
        let injections: Vec<Injection> = (0..8)
            .map(|t| Injection {
                at_tick: t,
                lp: (t as usize * 3) % 12,
                event: Event::injection(t + 1, t * 2, 4),
            })
            .collect();
        let run = |parallelism: usize| {
            let opts =
                SimOptions { parallelism, parallel_min_active: 0, ..Default::default() };
            let mut e =
                engine_on(&g, 3, (0..12).map(|i| i % 3).collect(), injections.clone(), opts);
            let stats = e.run_to_completion();
            (stats, e.gvt(), e.take_epoch_counters())
        };
        let seq = run(1);
        let par = run(4);
        assert_eq!(seq, par, "parallel run diverged from sequential");
    }

    #[test]
    fn parallel_ranges_cover_multiword_worklists() {
        // 150 LPs span three bitset words, so the popcount-balanced
        // range split actually produces distinct non-empty per-worker
        // ranges (the 12-LP test above exercises the padding path).
        let g = line_graph(150);
        let injections: Vec<Injection> = (0..24)
            .map(|t| Injection {
                at_tick: t % 5,
                lp: (t as usize * 13) % 150,
                event: Event::injection(t + 1, t * 3, 5),
            })
            .collect();
        let run = |parallelism: usize| {
            let opts =
                SimOptions { parallelism, parallel_min_active: 0, ..Default::default() };
            let mut e =
                engine_on(&g, 3, (0..150).map(|i| i % 3).collect(), injections.clone(), opts);
            let stats = e.run_to_completion();
            (stats, e.gvt(), e.take_epoch_counters())
        };
        let seq = run(1);
        for p in [2usize, 3] {
            assert_eq!(seq, run(p), "parallelism {p} diverged from sequential");
        }
    }
}

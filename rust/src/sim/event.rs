//! Events of the limited-scope flooded packet-flow model (§6.1).
//!
//! Each packet flood is a **thread** of events with a unique id. An LP
//! holds at most one live event per thread ("forward to all neighbors
//! that have not yet received it"), so `(lp, thread)` identifies an
//! event instance. Three kinds exist, mirroring the paper: forwarding
//! events (`ProcessForward`, hop budget left), terminal events
//! (`ProcessOnly`, hop budget exhausted) and anti-message `Rollback`
//! events (the default type every optimistic simulator needs).

/// Unique id of a packet-flood thread.
pub type ThreadId = u64;

/// Simulation (virtual) time.
pub type SimTime = u64;

/// Wall-clock tick count.
pub type WallTime = u64;

/// Event type (paper Table II `event-type`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Process and forward to unseen neighbors (hop budget > 0).
    ProcessForward,
    /// Process only; the flood stops here (hop budget = 0).
    ProcessOnly,
    /// Anti-message: cancel this thread at the receiver.
    Rollback,
}

impl EventKind {
    /// Base processing time in wall-clock ticks (`get_process_time` in
    /// Fig. 4/6), before scaling by machine occupancy.
    pub fn base_process_time(self, base: WallTime, rollback_base: WallTime) -> WallTime {
        match self {
            EventKind::ProcessForward | EventKind::ProcessOnly => base,
            EventKind::Rollback => rollback_base,
        }
    }

    /// Canonical intra-tick ordering rank: anti-messages annihilate
    /// before same-time forwards are processed, so `Rollback` sorts
    /// first. Shared by the LP heaps, the snapshot pending-sort key and
    /// the reference engine — one definition, one tie-break rule.
    #[inline]
    pub fn rank(self) -> u8 {
        match self {
            EventKind::Rollback => 0,
            EventKind::ProcessForward | EventKind::ProcessOnly => 1,
        }
    }
}

/// One event in an LP's event list (paper Table II columns `event-list`,
/// `event-time`, `event-type`, `event-tick`, `event-count`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Thread (packet flood) this event belongs to.
    pub thread: ThreadId,
    /// Execution timestamp in simulation time.
    pub time: SimTime,
    pub kind: EventKind,
    /// Remaining wall-clock ticks before this event becomes processable
    /// (models event-transfer delay; decremented once per tick).
    pub tick: WallTime,
    /// Remaining hop budget of the flood (`event-count`).
    pub count: u32,
}

impl Event {
    /// A fresh packet injection at `lp`-side with full hop budget.
    pub fn injection(thread: ThreadId, time: SimTime, hops: u32) -> Event {
        Event {
            thread,
            time,
            kind: if hops > 0 { EventKind::ProcessForward } else { EventKind::ProcessOnly },
            tick: 0,
            count: hops,
        }
    }

    /// The event forwarded to a neighbor: one hop consumed, timestamp
    /// advanced by the per-hop simulation latency, wall-clock arrival
    /// delayed by `transfer_delay`.
    pub fn forwarded(&self, hop_latency: SimTime, transfer_delay: WallTime) -> Event {
        debug_assert!(self.count > 0, "forwarding an exhausted event");
        let count = self.count - 1;
        Event {
            thread: self.thread,
            time: self.time + hop_latency,
            kind: if count > 0 { EventKind::ProcessForward } else { EventKind::ProcessOnly },
            tick: transfer_delay,
            count,
        }
    }

    /// The anti-message cancelling this event at its receiver.
    pub fn rollback_for(&self, transfer_delay: WallTime) -> Event {
        Event {
            thread: self.thread,
            time: self.time,
            kind: EventKind::Rollback,
            tick: transfer_delay,
            count: 0,
        }
    }

    /// Ready to process this tick?
    #[inline]
    pub fn ready(&self) -> bool {
        self.tick == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn injection_kind_follows_hops() {
        assert_eq!(Event::injection(1, 10, 3).kind, EventKind::ProcessForward);
        assert_eq!(Event::injection(1, 10, 0).kind, EventKind::ProcessOnly);
    }

    #[test]
    fn forwarding_consumes_hop_and_advances_time() {
        let e = Event::injection(7, 100, 2);
        let f = e.forwarded(1, 3);
        assert_eq!(f.thread, 7);
        assert_eq!(f.time, 101);
        assert_eq!(f.count, 1);
        assert_eq!(f.tick, 3);
        assert_eq!(f.kind, EventKind::ProcessForward);
        let g = f.forwarded(1, 0);
        assert_eq!(g.kind, EventKind::ProcessOnly);
        assert_eq!(g.count, 0);
    }

    #[test]
    fn rollback_carries_thread_and_time() {
        let e = Event::injection(9, 55, 1);
        let r = e.rollback_for(2);
        assert_eq!(r.kind, EventKind::Rollback);
        assert_eq!(r.thread, 9);
        assert_eq!(r.time, 55);
        assert_eq!(r.tick, 2);
    }

    #[test]
    fn readiness_follows_tick() {
        let mut e = Event::injection(1, 1, 1);
        assert!(e.ready());
        e.tick = 2;
        assert!(!e.ready());
    }

    #[test]
    fn process_time_by_kind() {
        assert_eq!(EventKind::ProcessForward.base_process_time(4, 2), 4);
        assert_eq!(EventKind::Rollback.base_process_time(4, 2), 2);
    }

    #[test]
    fn rollbacks_rank_before_forwards() {
        assert_eq!(EventKind::Rollback.rank(), 0);
        assert_eq!(EventKind::ProcessForward.rank(), 1);
        assert_eq!(EventKind::ProcessOnly.rank(), 1);
    }
}

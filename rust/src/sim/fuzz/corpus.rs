//! Corpus persistence: replayable JSON fuzz cases ([`FuzzCase`]) under
//! `results/fuzz_corpus/`, loaded in deterministic file-name order and
//! saved with the exact fixture and evaluation settings each finding
//! scored under.

use std::fs;
use std::path::{Path, PathBuf};

use crate::sim::scenario::DriftSchedule;
use crate::util::bench::{parse_json, JsonVal};

use super::{EvalOptions, FuzzFixture, FuzzOutcome, Objectives, CORPUS_FORMAT};

/// One persisted corpus entry: the fixture it scored on, the schedule
/// genome, the evaluation settings the scores were measured under, and
/// (for fuzzer-found entries) the objectives recorded at find time —
/// replays under the stored settings must reproduce them
/// byte-identically.
#[derive(Debug, Clone)]
pub struct FuzzCase {
    pub name: String,
    pub fixture: FuzzFixture,
    pub schedule: DriftSchedule,
    /// Settings the stored objectives were measured under (`None` =
    /// [`EvalOptions::default`]).
    pub eval: Option<EvalOptions>,
    pub objectives: Option<Objectives>,
}

impl FuzzCase {
    /// The evaluation settings replays of this case should use.
    pub fn eval_options(&self) -> EvalOptions {
        self.eval.clone().unwrap_or_default()
    }

    pub fn to_json(&self) -> JsonVal {
        let mut fields = vec![
            ("format".into(), JsonVal::Str(CORPUS_FORMAT.into())),
            ("name".into(), JsonVal::Str(self.name.clone())),
            ("fixture".into(), self.fixture.to_json()),
            ("schedule".into(), self.schedule.to_json()),
        ];
        match &self.eval {
            Some(eval) => fields.push(("eval".into(), eval.to_json())),
            None => fields.push(("eval".into(), JsonVal::Null)),
        }
        match &self.objectives {
            Some(obj) => fields.push(("objectives".into(), obj.to_json())),
            None => fields.push(("objectives".into(), JsonVal::Null)),
        }
        JsonVal::Obj(fields)
    }

    pub fn from_json(v: &JsonVal) -> Result<FuzzCase, String> {
        if let Some(fmt) = v.get("format").and_then(JsonVal::as_str) {
            if !fmt.starts_with("gtip-fuzz-case") {
                return Err(format!("unknown corpus format {fmt:?}"));
            }
        }
        let name = v.get("name").and_then(JsonVal::as_str).unwrap_or("unnamed").to_string();
        let fixture =
            FuzzFixture::from_json(v.get("fixture").ok_or("corpus case: missing fixture")?)?;
        let schedule =
            DriftSchedule::from_json(v.get("schedule").ok_or("corpus case: missing schedule")?)?;
        let eval = match v.get("eval") {
            None => None,
            Some(e) if e.is_null() => None,
            Some(e) => Some(EvalOptions::from_json(e)?),
        };
        let objectives = match v.get("objectives") {
            None => None,
            Some(o) if o.is_null() => None,
            Some(o) => Some(Objectives::from_json(o)?),
        };
        Ok(FuzzCase { name, fixture, schedule, eval, objectives })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<FuzzCase, String> {
        let path = path.as_ref();
        let text = fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let v = parse_json(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        FuzzCase::from_json(&v).map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut text = self.to_json().render();
        text.push('\n');
        fs::write(path, text)
    }
}

/// Load every `*.json` corpus entry under `dir`, sorted by file name
/// (deterministic replay order). A missing directory is an empty
/// corpus, not an error.
pub fn load_corpus(dir: impl AsRef<Path>) -> Result<Vec<FuzzCase>, String> {
    let dir = dir.as_ref();
    let mut paths: Vec<PathBuf> = match fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().map_or(false, |x| x == "json"))
            .collect(),
        Err(_) => return Ok(Vec::new()),
    };
    paths.sort();
    paths.iter().map(FuzzCase::load).collect()
}

/// Persist a campaign's found schedules under `dir` as
/// `<name>.json` (committed seed entries use the `seed-` prefix and are
/// never overwritten by this). Each finding carries the exact fixture
/// and evaluation settings it scored under — the configuration is part
/// of the fuzzed space — so replays reproduce the stored objectives
/// exactly. Returns the written paths.
pub fn save_corpus(dir: impl AsRef<Path>, outcome: &FuzzOutcome) -> std::io::Result<Vec<PathBuf>> {
    let dir = dir.as_ref();
    fs::create_dir_all(dir)?;
    let mut written = Vec::new();
    for f in &outcome.found {
        let case = FuzzCase {
            name: f.name.clone(),
            fixture: f.fixture,
            schedule: f.schedule.clone(),
            eval: Some(f.eval.clone()),
            objectives: Some(f.objectives.clone()),
        };
        let path = dir.join(format!("{}.json", f.name));
        case.save(&path)?;
        written.push(path);
    }
    Ok(written)
}

//! Adversarial scenario fuzzing: search the [`DriftSchedule`] genome
//! space for worst-case drift workloads, shrink what is found, and
//! persist it as a replayable corpus.
//!
//! The paper's value proposition is that iterative game-theoretic
//! repartitioning tracks *drifting* load (§6); the hand-written
//! `sim::scenario` library only samples friendly drift. This module
//! closes the ROADMAP item "generate adversarial drift schedules that
//! maximize the frozen-vs-rebalanced gap":
//!
//! * **Evaluation** ([`evaluate`]): compile a candidate genome on a
//!   deterministic [`FuzzFixture`], run the closed loop's
//!   frozen-vs-rebalanced comparison (`sim::dynamic`), and record
//!   [`Objectives`] — the frozen/rebalanced tick gap, rollback volume,
//!   migration churn, potential-descent violations (Thm 4.1 says there
//!   must be none), and a **differential oracle**: the optimized engine
//!   must stay bit-identical to `sim::reference` on the schedule.
//!   Divergence or a descent violation dominates the score — those are
//!   engine bugs, the most valuable find of all.
//! * **Search** ([`run_fuzz`]): seeded hill-climbing with mutation and
//!   crossover over a population initialized from the four hand-written
//!   scenario genomes plus an [`epoch_locked_relocation`] template
//!   (maximally concentrated hot spot relocating every refinement
//!   epoch). Candidates carry their *engine configuration* — machine
//!   speeds ([`FuzzFixture::speed_seed`]), transfer delays, and epoch
//!   length ([`Mutator::mutate_config`]) — so the campaign fuzzes the
//!   simulator's parameter space, not just the workload. Fully
//!   deterministic per seed.
//! * **Shrinking** ([`shrink`]): delta-debug the winning genome —
//!   remove genes, halve thread counts and windows — to a minimal
//!   schedule that still preserves the score (or the bug).
//! * **Corpus** ([`FuzzCase`], [`load_corpus`], [`save_corpus`]):
//!   schedules persist as JSON under `results/fuzz_corpus/`; committed
//!   `seed-*.json` entries are replayed by `rust/tests/
//!   fuzz_regressions.rs` (descent + byte-identical scores) and
//!   `rust/tests/equivalence_engine.rs` (reference-engine equality at
//!   parallelism 1/2/4), and promoted into `bench_dynamic`'s
//!   `results/BENCH_sim.json` report.

use crate::game::cost::Framework;
use crate::graph::generators::preferential_attachment;
use crate::graph::Graph;
use crate::partition::initial::grow_partition;
use crate::partition::{MachineConfig, Partition};
use crate::sim::dynamic::{compare_frozen_vs_rebalanced, DynamicOptions, WeightEstimator};
use crate::sim::engine::{Injection, SimEngine, SimOptions};
use crate::sim::reference::ReferenceEngine;
use crate::sim::scenario::{
    far_apart_centers, phase_windows, DriftGene, DriftSchedule, GeneKind, ScenarioKind,
    ScenarioOptions,
};
use crate::util::bench::JsonVal;
use crate::util::rng::Pcg32;

mod corpus;
mod mutate;

pub use corpus::{load_corpus, save_corpus, FuzzCase};
pub use mutate::{shrink, shrink_steps, Mutator};

/// Corpus file format tag.
pub const CORPUS_FORMAT: &str = "gtip-fuzz-case-v1";

/// The deterministic evaluation substrate a schedule is scored on: one
/// seed pins the graph, the machine pool, and the App.-A initial
/// partition (the genome itself carries its own injection seed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FuzzFixture {
    pub graph_seed: u64,
    pub nodes: usize,
    pub machines: usize,
    /// Machine-speed heterogeneity seed. `0` (the default) keeps the
    /// homogeneous pool every pre-config-fuzz corpus entry was measured
    /// on; any other value derives a mild heterogeneous speed vector
    /// (≈1:3 max spread) from an RNG stream separate from the graph
    /// stream, so the graph itself never shifts under a speed reroll.
    pub speed_seed: u64,
}

impl Default for FuzzFixture {
    fn default() -> Self {
        FuzzFixture { graph_seed: 2011, nodes: 96, machines: 4, speed_seed: 0 }
    }
}

impl FuzzFixture {
    /// Materialize the fixture. Equal fixtures produce identical
    /// graphs, machine pools, and initial partitions.
    pub fn build(&self) -> (Graph, MachineConfig, Partition) {
        assert!(self.nodes > 0 && self.machines > 0, "degenerate fuzz fixture");
        let mut rng = Pcg32::new(self.graph_seed);
        let graph = preferential_attachment(self.nodes, 2, &mut rng);
        let machines = self.build_machines();
        let initial = grow_partition(&graph, &machines, &mut rng);
        (graph, machines, initial)
    }

    /// The machine pool alone (speeds normalized).
    pub fn build_machines(&self) -> MachineConfig {
        if self.speed_seed == 0 {
            MachineConfig::homogeneous(self.machines)
        } else {
            let mut srng = Pcg32::new(self.speed_seed ^ 0x5EED_CAFE);
            let raw: Vec<f64> = (0..self.machines).map(|_| 0.5 + srng.next_f64()).collect();
            MachineConfig::from_speeds(&raw)
        }
    }

    pub fn to_json(&self) -> JsonVal {
        JsonVal::Obj(vec![
            ("graph_seed".into(), JsonVal::Int(self.graph_seed)),
            ("nodes".into(), JsonVal::Int(self.nodes as u64)),
            ("machines".into(), JsonVal::Int(self.machines as u64)),
            ("speed_seed".into(), JsonVal::Int(self.speed_seed)),
        ])
    }

    pub fn from_json(v: &JsonVal) -> Result<FuzzFixture, String> {
        let field = |k: &str| {
            v.get(k)
                .and_then(JsonVal::as_u64)
                .ok_or_else(|| format!("fixture: missing integer field {k:?}"))
        };
        Ok(FuzzFixture {
            graph_seed: field("graph_seed")?,
            nodes: field("nodes")? as usize,
            machines: field("machines")? as usize,
            // Absent in pre-config-fuzz corpus files: default to the
            // homogeneous pool those entries were measured on. A
            // present-but-wrong-typed seed is a clean parse error.
            speed_seed: match v.get("speed_seed") {
                None => 0,
                Some(raw) => raw.as_u64().ok_or_else(|| {
                    format!("fixture: speed_seed {raw:?} is not an unsigned integer")
                })?,
            },
        })
    }
}

/// How a candidate schedule is evaluated. The simulator configuration
/// knobs here (`epoch_ticks`, the transfer delays) are themselves part
/// of the fuzzed search space — see [`Mutator::mutate_config`].
#[derive(Debug, Clone, PartialEq)]
pub struct EvalOptions {
    /// Simulation window per refinement epoch of the rebalanced arm.
    pub epoch_ticks: u64,
    pub framework: Framework,
    /// In-game migration surcharge (`DynamicOptions::migration_charge`)
    /// the rebalanced arm prices moves at — lets campaigns hunt
    /// hysteresis pathologies at nonzero charge levels. Default 0.
    pub migration_charge: f64,
    /// Wall-clock delay of a cross-machine event transfer
    /// (`SimOptions::inter_machine_delay`). Default 3, matching the
    /// engine default every pre-config-fuzz corpus entry replays under.
    pub inter_machine_delay: u64,
    /// Wall-clock delay of an intra-machine event transfer
    /// (`SimOptions::intra_machine_delay`). Default 0.
    pub intra_machine_delay: u64,
    /// Safety cap per arm (a truncated rebalanced arm scores as a
    /// finding — the workload outran the balancer).
    pub max_ticks: u64,
    /// Cross-check the schedule against `sim::reference` (bit-equality
    /// of `SimStats`, `EpochCounters`, and final GVT).
    pub oracle: bool,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            epoch_ticks: 150,
            framework: Framework::A,
            migration_charge: 0.0,
            inter_machine_delay: 3,
            intra_machine_delay: 0,
            max_ticks: 400_000,
            oracle: true,
        }
    }
}

impl EvalOptions {
    pub fn to_json(&self) -> JsonVal {
        JsonVal::Obj(vec![
            ("epoch_ticks".into(), JsonVal::Int(self.epoch_ticks)),
            ("framework".into(), JsonVal::Str(format!("{}", self.framework))),
            ("migration_charge".into(), JsonVal::Num(self.migration_charge)),
            ("inter_machine_delay".into(), JsonVal::Int(self.inter_machine_delay)),
            ("intra_machine_delay".into(), JsonVal::Int(self.intra_machine_delay)),
            ("max_ticks".into(), JsonVal::Int(self.max_ticks)),
            ("oracle".into(), JsonVal::Bool(self.oracle)),
        ])
    }

    pub fn from_json(v: &JsonVal) -> Result<EvalOptions, String> {
        let field = |k: &str| {
            v.get(k)
                .and_then(JsonVal::as_u64)
                .ok_or_else(|| format!("eval: missing integer field {k:?}"))
        };
        // Absent in pre-config-fuzz corpus files: default to the engine
        // defaults those entries were measured under. Wrong-typed values
        // are clean parse errors, never a silent default.
        let opt_field = |k: &str, default: u64| match v.get(k) {
            None => Ok(default),
            Some(raw) => raw
                .as_u64()
                .ok_or_else(|| format!("eval: {k} {raw:?} is not an unsigned integer")),
        };
        Ok(EvalOptions {
            epoch_ticks: field("epoch_ticks")?,
            framework: v
                .get("framework")
                .and_then(JsonVal::as_str)
                .ok_or("eval: missing framework")?
                .parse::<Framework>()?,
            // Absent in pre-charge corpus files: default to the free game
            // so committed seed-* entries replay byte-identically. A
            // present-but-invalid charge is a clean parse error, not a
            // downstream assert panic.
            migration_charge: match v.get("migration_charge") {
                None => 0.0,
                Some(raw) => {
                    let c = raw.as_f64().ok_or_else(|| {
                        format!("eval: migration_charge {raw:?} is not a number")
                    })?;
                    if !(c.is_finite() && c >= 0.0) {
                        return Err(format!(
                            "eval: migration_charge {c} must be finite and non-negative"
                        ));
                    }
                    c
                }
            },
            inter_machine_delay: opt_field("inter_machine_delay", 3)?,
            intra_machine_delay: opt_field("intra_machine_delay", 0)?,
            max_ticks: field("max_ticks")?,
            oracle: v.get("oracle").and_then(JsonVal::as_bool).unwrap_or(true),
        })
    }
}

/// Closed-loop objectives of one evaluated schedule. `score()` is what
/// the search maximizes; bug-class signals dominate the gap term.
#[derive(Debug, Clone, PartialEq)]
pub struct Objectives {
    pub frozen_ticks: u64,
    pub rebalanced_ticks: u64,
    /// `frozen / rebalanced` total time — the frozen-vs-rebalanced gap
    /// the fuzzer maximizes (the paper's speedup metric).
    pub gap: f64,
    /// Rollback episodes of the rebalanced arm.
    pub rollbacks: u64,
    /// Migration churn: LP transfers executed by the rebalanced arm.
    pub transfers: u64,
    pub refinements: u64,
    /// Epochs whose potential rose (Thm 4.1 violations; must be 0).
    pub descent_violations: u64,
    pub frozen_truncated: bool,
    pub rebalanced_truncated: bool,
    /// Optimized engine diverged from `sim::reference` on this
    /// schedule.
    pub oracle_divergence: bool,
}

/// Weight of the churn term in [`Objectives::score`]: small relative
/// to a typical gap so it tie-breaks rather than dominates, but enough
/// that schedules provoking pathological migration churn (the
/// hysteresis failure mode the charge exists to damp) rank above
/// equal-gap quiet ones and surface in campaigns.
pub const CHURN_SCORE_WEIGHT: f64 = 0.002;

impl Objectives {
    /// Search score: the gap, plus a churn term ([`CHURN_SCORE_WEIGHT`]
    /// per transfer of the rebalanced arm), plus dominant bounties for
    /// bug-class findings (descent violations, truncation livelock,
    /// oracle divergence).
    pub fn score(&self) -> f64 {
        let mut s = self.gap;
        s += CHURN_SCORE_WEIGHT * self.transfers as f64;
        s += 1_000.0 * self.descent_violations as f64;
        if self.rebalanced_truncated {
            s += 10_000.0;
        }
        if self.oracle_divergence {
            s += 1_000_000.0;
        }
        s
    }

    /// Does this evaluation expose an engine/theory bug (as opposed to
    /// merely a large gap)?
    pub fn is_bug(&self) -> bool {
        self.oracle_divergence || self.descent_violations > 0 || self.rebalanced_truncated
    }

    /// Exact (bit-level) equality — the determinism contract the
    /// regression suite asserts: same seeds ⇒ byte-identical scores.
    pub fn bit_eq(&self, other: &Objectives) -> bool {
        self.frozen_ticks == other.frozen_ticks
            && self.rebalanced_ticks == other.rebalanced_ticks
            && self.gap.to_bits() == other.gap.to_bits()
            && self.rollbacks == other.rollbacks
            && self.transfers == other.transfers
            && self.refinements == other.refinements
            && self.descent_violations == other.descent_violations
            && self.frozen_truncated == other.frozen_truncated
            && self.rebalanced_truncated == other.rebalanced_truncated
            && self.oracle_divergence == other.oracle_divergence
    }

    pub fn to_json(&self) -> JsonVal {
        JsonVal::Obj(vec![
            ("frozen_ticks".into(), JsonVal::Int(self.frozen_ticks)),
            ("rebalanced_ticks".into(), JsonVal::Int(self.rebalanced_ticks)),
            ("gap".into(), JsonVal::Num(self.gap)),
            ("rollbacks".into(), JsonVal::Int(self.rollbacks)),
            ("transfers".into(), JsonVal::Int(self.transfers)),
            ("refinements".into(), JsonVal::Int(self.refinements)),
            ("descent_violations".into(), JsonVal::Int(self.descent_violations)),
            ("frozen_truncated".into(), JsonVal::Bool(self.frozen_truncated)),
            ("rebalanced_truncated".into(), JsonVal::Bool(self.rebalanced_truncated)),
            ("oracle_divergence".into(), JsonVal::Bool(self.oracle_divergence)),
        ])
    }

    pub fn from_json(v: &JsonVal) -> Result<Objectives, String> {
        let int = |k: &str| {
            v.get(k)
                .and_then(JsonVal::as_u64)
                .ok_or_else(|| format!("objectives: missing integer field {k:?}"))
        };
        let flag = |k: &str| {
            v.get(k)
                .and_then(JsonVal::as_bool)
                .ok_or_else(|| format!("objectives: missing bool field {k:?}"))
        };
        Ok(Objectives {
            frozen_ticks: int("frozen_ticks")?,
            rebalanced_ticks: int("rebalanced_ticks")?,
            gap: v
                .get("gap")
                .and_then(JsonVal::as_f64)
                .ok_or("objectives: missing number field \"gap\"")?,
            rollbacks: int("rollbacks")?,
            transfers: int("transfers")?,
            refinements: int("refinements")?,
            descent_violations: int("descent_violations")?,
            frozen_truncated: flag("frozen_truncated")?,
            rebalanced_truncated: flag("rebalanced_truncated")?,
            oracle_divergence: flag("oracle_divergence")?,
        })
    }
}

/// Does the optimized engine agree bit-for-bit with the naive
/// reference stepper on this workload? (`SimStats` + `EpochCounters` +
/// final GVT.)
fn reference_agrees(
    graph: &Graph,
    machines: &MachineConfig,
    initial: &Partition,
    injections: &[Injection],
    sim: &SimOptions,
) -> bool {
    let mut reference = ReferenceEngine::new(
        graph,
        machines.clone(),
        initial.clone(),
        sim.clone(),
        injections.to_vec(),
    );
    let ref_stats = reference.run_to_completion();
    let mut optimized = SimEngine::new(
        graph,
        machines.clone(),
        initial.clone(),
        sim.clone(),
        injections.to_vec(),
    );
    let opt_stats = optimized.run_to_completion();
    ref_stats == opt_stats
        && reference.gvt() == optimized.gvt()
        && reference.take_epoch_counters() == optimized.take_epoch_counters()
}

/// Score one schedule on a fixture: closed-loop frozen-vs-rebalanced
/// comparison plus (optionally) the `sim::reference` differential
/// oracle. Fully deterministic: equal inputs produce bit-identical
/// [`Objectives`].
pub fn evaluate(
    fixture: &FuzzFixture,
    schedule: &DriftSchedule,
    eval: &EvalOptions,
) -> Result<Objectives, String> {
    let (graph, machines, initial) = fixture.build();
    schedule.validate(graph.node_count())?;
    let injections = schedule.compile(&graph);
    let options = DynamicOptions {
        sim: SimOptions {
            max_ticks: eval.max_ticks,
            inter_machine_delay: eval.inter_machine_delay,
            intra_machine_delay: eval.intra_machine_delay,
            ..Default::default()
        },
        epoch_ticks: eval.epoch_ticks,
        framework: eval.framework,
        migration_charge: eval.migration_charge,
        ..Default::default()
    };
    let report = compare_frozen_vs_rebalanced(
        &graph,
        &machines,
        &initial,
        &injections,
        WeightEstimator::ewma(0.5),
        &options,
    );
    let oracle_divergence =
        eval.oracle && !reference_agrees(&graph, &machines, &initial, &injections, &options.sim);
    Ok(Objectives {
        frozen_ticks: report.frozen.total_time(),
        rebalanced_ticks: report.rebalanced.total_time(),
        gap: report.speedup(),
        rollbacks: report.rebalanced.stats.rollbacks,
        transfers: report.rebalanced.transfers as u64,
        refinements: report.rebalanced.refinements() as u64,
        descent_violations: report.rebalanced.descent_violations() as u64,
        frozen_truncated: report.frozen.stats.truncated,
        rebalanced_truncated: report.rebalanced.stats.truncated,
        oracle_divergence,
    })
}


// ---------------------------------------------------------------------------
// The search loop
// ---------------------------------------------------------------------------

/// Knobs of one [`run_fuzz`] campaign.
#[derive(Debug, Clone)]
pub struct FuzzOptions {
    /// Evaluation budget of the search phase (baselines included);
    /// shrinking spends up to `budget / 4` extra per winner.
    pub budget: usize,
    /// Master seed: drives the search RNG and names the found corpus.
    pub seed: u64,
    pub fixture: FuzzFixture,
    /// Horizon every candidate spreads its injections across.
    pub horizon_ticks: u64,
    /// Thread budget every candidate is normalized to.
    pub thread_budget: u32,
    pub hop_limit: u32,
    pub eval: EvalOptions,
    /// How many worst schedules to keep (and shrink).
    pub top_k: usize,
    pub shrink: bool,
    pub verbose: bool,
}

impl Default for FuzzOptions {
    fn default() -> Self {
        FuzzOptions {
            budget: 200,
            seed: 2011,
            fixture: FuzzFixture::default(),
            horizon_ticks: 1_200,
            thread_budget: 120,
            hop_limit: 4,
            eval: EvalOptions::default(),
            top_k: 3,
            shrink: true,
            verbose: false,
        }
    }
}

/// One worst-case finding a campaign produced: the schedule genome
/// plus the exact engine configuration (fixture + eval settings) it
/// scored worst under — the configuration is part of the search space,
/// so it must persist with the schedule for the replay to reproduce.
#[derive(Debug, Clone)]
pub struct FoundSchedule {
    /// 1-based rank by score (1 = worst found).
    pub rank: usize,
    pub name: String,
    pub fixture: FuzzFixture,
    pub eval: EvalOptions,
    pub schedule: DriftSchedule,
    pub objectives: Objectives,
    pub genes_before_shrink: usize,
}

/// Result of a [`run_fuzz`] campaign.
#[derive(Debug, Clone)]
pub struct FuzzOutcome {
    /// The four hand-written scenario genomes' objectives on the same
    /// fixture and budget — the bar the search has to clear.
    pub handwritten: Vec<(ScenarioKind, Objectives)>,
    pub handwritten_best_gap: f64,
    /// Top-k worst schedules, shrunk, rank order.
    pub found: Vec<FoundSchedule>,
    /// Total evaluations spent (search + shrink).
    pub evaluations: usize,
}

impl FuzzOutcome {
    /// Did the campaign find a schedule whose gap exceeds every
    /// hand-written scenario's?
    pub fn beat_handwritten(&self) -> bool {
        self.found.iter().any(|f| f.objectives.gap > self.handwritten_best_gap)
    }
}

/// One refinement epoch in per-mille of the horizon (the grid both the
/// mutator's epoch-align operator and the seed template snap to).
fn epoch_pm_of(epoch_ticks: u64, horizon_ticks: u64) -> u32 {
    ((epoch_ticks.saturating_mul(1000) / horizon_ticks.max(1)) as u32).clamp(1, 1000)
}

/// The adversarial seed template: a maximally concentrated hot spot
/// that relocates to a far-apart center once per refinement epoch —
/// the drift pattern a frozen partition tracks worst.
pub fn epoch_locked_relocation(
    graph: &Graph,
    options: &FuzzOptions,
    rng: &mut Pcg32,
) -> DriftSchedule {
    let epoch_pm = epoch_pm_of(options.eval.epoch_ticks, options.horizon_ticks);
    let phases = ((1000 / epoch_pm) as usize).clamp(2, 16);
    let centers = far_apart_centers(graph, phases, rng);
    let windows = phase_windows(phases);
    let mut genes: Vec<DriftGene> = (0..phases)
        .map(|p| DriftGene {
            kind: GeneKind::Hotspot,
            start_pm: windows[p].0,
            len_pm: windows[p].1,
            center: centers[p],
            radius: 1,
            threads: 1,
            hot_pm: 1000,
        })
        .collect();
    let budget = options.thread_budget.max(phases as u32);
    for gene in genes.iter_mut() {
        gene.threads = (budget / phases as u32).max(1);
    }
    let used: u32 = genes.iter().map(|g| g.threads).sum();
    if used < budget {
        genes[0].threads += budget - used;
    }
    DriftSchedule {
        seed: rng.next_u64(),
        horizon_ticks: options.horizon_ticks,
        hop_limit: options.hop_limit,
        ts_rate_pm: 500,
        ts_jitter: 8,
        genes,
    }
}

/// One search point: the schedule genome together with the engine
/// configuration it is evaluated under. Mutation touches either half.
#[derive(Debug, Clone, PartialEq)]
struct Candidate {
    fixture: FuzzFixture,
    eval: EvalOptions,
    schedule: DriftSchedule,
}

fn admit(
    cand: Candidate,
    obj: Objectives,
    elites: &mut Vec<(Candidate, Objectives)>,
    found: &mut Vec<(Candidate, Objectives)>,
) {
    let by_score = |a: &(Candidate, Objectives), b: &(Candidate, Objectives)| {
        b.1.score().partial_cmp(&a.1.score()).unwrap_or(std::cmp::Ordering::Equal)
    };
    if !found.iter().any(|(c, _)| *c == cand) {
        found.push((cand.clone(), obj.clone()));
        found.sort_by(by_score);
        found.truncate(32);
    }
    if !elites.iter().any(|(c, _)| *c == cand) {
        elites.push((cand, obj));
        elites.sort_by(by_score);
        elites.truncate(6);
    }
}

/// Run one fuzzing campaign: score the hand-written baselines, seed the
/// population with their genomes plus the epoch-locked relocation
/// template, hill-climb with mutation/crossover until the budget is
/// spent, then shrink the top-k worst schedules. Deterministic per
/// [`FuzzOptions`].
pub fn run_fuzz(options: &FuzzOptions) -> Result<FuzzOutcome, String> {
    if options.budget == 0 {
        return Err("--budget must be >= 1".into());
    }
    let (graph, _machines, _initial) = options.fixture.build();
    let mut rng = Pcg32::new(options.seed ^ 0xF0_55ED);
    let mutator = Mutator {
        nodes: options.fixture.nodes,
        thread_budget: options.thread_budget,
        epoch_pm: epoch_pm_of(options.eval.epoch_ticks, options.horizon_ticks),
        max_genes: 24,
    };
    let mut evals = 0usize;

    // Baselines: the bar to clear, and the seed population.
    let scen_opts = ScenarioOptions {
        threads: options.thread_budget.max(1) as usize,
        horizon_ticks: options.horizon_ticks,
        hop_limit: options.hop_limit,
        ..Default::default()
    };
    let mut handwritten = Vec::new();
    let mut handwritten_best_gap = 0.0f64;
    let mut elites: Vec<(Candidate, Objectives)> = Vec::new();
    let mut found: Vec<(Candidate, Objectives)> = Vec::new();
    let base = |schedule: DriftSchedule| Candidate {
        fixture: options.fixture,
        eval: options.eval.clone(),
        schedule,
    };
    for kind in ScenarioKind::ALL {
        let (genome, _) = kind.genome(&graph, &scen_opts, &mut rng);
        evals += 1;
        let obj = evaluate(&options.fixture, &genome, &options.eval)?;
        if options.verbose {
            println!(
                "  baseline {:<8} gap {:.3}  (frozen {:>6} / rebalanced {:>6}, rollbacks {}, transfers {})",
                kind.name(),
                obj.gap,
                obj.frozen_ticks,
                obj.rebalanced_ticks,
                obj.rollbacks,
                obj.transfers
            );
        }
        handwritten_best_gap = handwritten_best_gap.max(obj.gap);
        admit(base(genome), obj.clone(), &mut elites, &mut found);
        handwritten.push((kind, obj));
    }
    if evals < options.budget {
        let template = epoch_locked_relocation(&graph, options, &mut rng);
        evals += 1;
        let obj = evaluate(&options.fixture, &template, &options.eval)?;
        if options.verbose {
            println!("  template epoch-locked-relocation gap {:.3}", obj.gap);
        }
        admit(base(template), obj, &mut elites, &mut found);
    }

    // Hill-climb with restarts. Mutation touches the schedule genome
    // or (one time in four on the mutate arm) the engine configuration
    // itself — machine speeds, transfer delays, epoch length — so a
    // campaign also searches the simulator's own parameter space.
    let mut best_score = found.first().map(|(_, o)| o.score()).unwrap_or(0.0);
    let mut attempts = 0usize;
    while evals < options.budget && attempts < options.budget.saturating_mul(20) {
        attempts += 1;
        let roll = rng.next_f64();
        let candidate = if elites.is_empty() || roll < 0.15 {
            base(mutator.random_schedule(options.horizon_ticks, options.hop_limit, &mut rng))
        } else if roll < 0.35 && elites.len() >= 2 {
            let i = rng.index(elites.len());
            let mut j = rng.index(elites.len());
            if j == i {
                j = (j + 1) % elites.len();
            }
            let (a, b) = (elites[i].0.clone(), elites[j].0.clone());
            let schedule = mutator.crossover(&a.schedule, &b.schedule, &mut rng);
            // The crossed schedule inherits parent a's configuration.
            Candidate { schedule, ..a }
        } else {
            let parent = elites[rng.index(elites.len())].0.clone();
            if rng.chance(0.25) {
                let (fixture, eval) = mutator.mutate_config(
                    &parent.fixture,
                    &parent.eval,
                    options.horizon_ticks,
                    &mut rng,
                );
                Candidate { fixture, eval, schedule: parent.schedule }
            } else {
                let schedule = mutator.mutate(&parent.schedule, &mut rng);
                Candidate { schedule, ..parent }
            }
        };
        if candidate.schedule.validate(graph.node_count()).is_err() {
            continue; // operators should keep validity; never score junk
        }
        evals += 1;
        let obj = evaluate(&candidate.fixture, &candidate.schedule, &candidate.eval)?;
        if obj.score() > best_score {
            best_score = obj.score();
            if options.verbose {
                println!(
                    "  [{evals:>4}/{:>4}] new worst case: score {:.3}, gap {:.3} ({} genes, rollbacks {}, transfers {}, speeds {}, delays {}/{}, epoch {})",
                    options.budget,
                    obj.score(),
                    obj.gap,
                    candidate.schedule.genes.len(),
                    obj.rollbacks,
                    obj.transfers,
                    if candidate.fixture.speed_seed == 0 { "homogeneous".into() } else { format!("seed {}", candidate.fixture.speed_seed) },
                    candidate.eval.inter_machine_delay,
                    candidate.eval.intra_machine_delay,
                    candidate.eval.epoch_ticks,
                );
            }
        }
        admit(candidate, obj, &mut elites, &mut found);
    }

    // Shrink the winners (each under its own found configuration).
    let winners: Vec<(Candidate, Objectives)> =
        found.iter().take(options.top_k.max(1)).cloned().collect();
    let shrink_budget_each = (options.budget / 4).clamp(8, 120);
    let mut out_found = Vec::new();
    for (rank, (cand, obj)) in winners.into_iter().enumerate() {
        let genes_before = cand.schedule.genes.len();
        let (small, small_obj) = if options.shrink {
            let floor = if obj.is_bug() {
                0.0 // the predicate is "bug preserved", not the score
            } else if obj.gap > handwritten_best_gap {
                // Preserve "exceeds every hand-written gap".
                handwritten_best_gap + 1e-9
            } else {
                obj.score() * 0.9
            };
            let (s, o, used) =
                shrink(&cand.fixture, &cand.schedule, &obj, &cand.eval, floor, shrink_budget_each);
            evals += used;
            (s, o)
        } else {
            (cand.schedule.clone(), obj)
        };
        if options.verbose {
            println!(
                "  worst #{:<2} {} -> {} genes, score {:.3}, gap {:.3}{}",
                rank + 1,
                genes_before,
                small.genes.len(),
                small_obj.score(),
                small_obj.gap,
                if small_obj.is_bug() { "  [BUG-CLASS FINDING]" } else { "" }
            );
        }
        out_found.push(FoundSchedule {
            rank: rank + 1,
            name: format!(
                "found-{}-r{}{}",
                options.seed,
                rank + 1,
                if small_obj.is_bug() { "-bug" } else { "" }
            ),
            fixture: cand.fixture,
            eval: cand.eval,
            schedule: small,
            objectives: small_obj,
            genes_before_shrink: genes_before,
        });
    }
    Ok(FuzzOutcome { handwritten, handwritten_best_gap, found: out_found, evaluations: evals })
}

#[cfg(test)]
mod tests {
    use std::fs;

    use crate::util::bench::parse_json;

    use super::*;

    fn tiny_fixture() -> FuzzFixture {
        FuzzFixture { graph_seed: 11, nodes: 48, machines: 3, speed_seed: 0 }
    }

    fn tiny_eval(oracle: bool) -> EvalOptions {
        EvalOptions { epoch_ticks: 120, max_ticks: 200_000, oracle, ..Default::default() }
    }

    fn tiny_mutator() -> Mutator {
        Mutator { nodes: 48, thread_budget: 36, epoch_pm: 200, max_genes: 12 }
    }

    #[test]
    fn evaluate_is_bit_deterministic_and_json_exact() {
        let fixture = tiny_fixture();
        let mut rng = Pcg32::new(5);
        let schedule = tiny_mutator().random_schedule(600, 4, &mut rng);
        let a = evaluate(&fixture, &schedule, &tiny_eval(false)).unwrap();
        let b = evaluate(&fixture, &schedule, &tiny_eval(false)).unwrap();
        assert!(a.bit_eq(&b), "same schedule, different objectives:\n{a:?}\n{b:?}");
        // JSON round trip is exact, including the f64 gap.
        let text = a.to_json().render();
        let back = Objectives::from_json(&parse_json(&text).unwrap()).unwrap();
        assert!(a.bit_eq(&back), "objectives drifted through JSON: {text}");
    }

    /// The churn term ranks high-transfer schedules above equal-gap
    /// quiet ones, and a charged evaluation (in-game surcharge) damps
    /// the rebalanced arm's churn on the same schedule.
    #[test]
    fn churn_term_and_charged_eval() {
        let fixture = tiny_fixture();
        let mut rng = Pcg32::new(31);
        let schedule = tiny_mutator().random_schedule(600, 4, &mut rng);
        let free = evaluate(&fixture, &schedule, &tiny_eval(false)).unwrap();
        assert!(
            (free.score() - (free.gap + CHURN_SCORE_WEIGHT * free.transfers as f64)).abs()
                < 1e-12,
            "score must include the churn term"
        );
        // A prohibitive in-game charge provably freezes the rebalanced
        // arm: no raw gain on this tiny fixture can approach 1e12
        // (cross-charge transfer-count comparisons at moderate levels
        // are trajectory-dependent and deliberately not asserted).
        let charged_eval =
            EvalOptions { migration_charge: 1e12, ..tiny_eval(false) };
        let charged = evaluate(&fixture, &schedule, &charged_eval).unwrap();
        assert_eq!(charged.transfers, 0, "a 1e12 charge must freeze the balancer");
        assert_eq!(charged.descent_violations, 0);
        // Charged eval settings round-trip through JSON.
        let back =
            EvalOptions::from_json(&parse_json(&charged_eval.to_json().render()).unwrap())
                .unwrap();
        assert_eq!(back.migration_charge, 1e12);
        // Pre-charge corpus JSON (no field) defaults to the free game.
        let legacy = EvalOptions::from_json(
            &parse_json(r#"{"epoch_ticks":120,"framework":"A","max_ticks":200000,"oracle":false}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(legacy.migration_charge, 0.0);
        // A present-but-invalid charge is a clean error, not a panic.
        let bad = EvalOptions::from_json(
            &parse_json(
                r#"{"epoch_ticks":120,"framework":"A","migration_charge":-5,"max_ticks":200000,"oracle":false}"#,
            )
            .unwrap(),
        );
        assert!(bad.is_err(), "negative corpus charge must be rejected at parse time");
        // Wrong-typed charge is an error too, never a silent 0.0.
        let typed = EvalOptions::from_json(
            &parse_json(
                r#"{"epoch_ticks":120,"framework":"A","migration_charge":"3.5","max_ticks":200000,"oracle":false}"#,
            )
            .unwrap(),
        );
        assert!(typed.is_err(), "string-typed corpus charge must be rejected at parse time");
    }

    #[test]
    fn oracle_agrees_on_generated_schedules() {
        let fixture = tiny_fixture();
        let mut rng = Pcg32::new(9);
        let schedule = tiny_mutator().random_schedule(500, 4, &mut rng);
        let obj = evaluate(&fixture, &schedule, &tiny_eval(true)).unwrap();
        assert!(!obj.oracle_divergence, "optimized engine diverged from sim::reference");
        assert_eq!(obj.descent_violations, 0, "Thm 4.1 violated: {obj:?}");
    }

    #[test]
    fn shrink_reduces_without_losing_the_property() {
        let fixture = tiny_fixture();
        let eval = tiny_eval(false);
        let mut rng = Pcg32::new(13);
        let mutator = tiny_mutator();
        let mut schedule = mutator.random_schedule(600, 4, &mut rng);
        for _ in 0..3 {
            schedule = mutator.mutate(&schedule, &mut rng);
        }
        let obj = evaluate(&fixture, &schedule, &eval).unwrap();
        let floor = obj.score() * 0.5;
        let (small, small_obj, used) = shrink(&fixture, &schedule, &obj, &eval, floor, 40);
        assert!(used > 0, "shrink never evaluated anything");
        assert!(small.genes.len() <= schedule.genes.len());
        assert!(small.total_threads() <= schedule.total_threads());
        assert!(small_obj.score() >= floor, "shrink lost the property");
        small.validate(fixture.nodes).unwrap();
        // Shrunk schedule still replays to the same objectives.
        let replay = evaluate(&fixture, &small, &eval).unwrap();
        assert!(replay.bit_eq(&small_obj));
    }

    #[test]
    fn corpus_saves_and_loads_round_trip() {
        let dir = std::env::temp_dir().join(format!("gtip_fuzz_corpus_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let fixture = tiny_fixture();
        let mut rng = Pcg32::new(21);
        let schedule = tiny_mutator().random_schedule(500, 4, &mut rng);
        let obj = evaluate(&fixture, &schedule, &tiny_eval(false)).unwrap();
        let outcome = FuzzOutcome {
            handwritten: Vec::new(),
            handwritten_best_gap: 0.0,
            found: vec![FoundSchedule {
                rank: 1,
                name: "found-test-r1".into(),
                fixture,
                eval: tiny_eval(false),
                schedule: schedule.clone(),
                objectives: obj.clone(),
                genes_before_shrink: schedule.genes.len(),
            }],
            evaluations: 1,
        };
        let written = save_corpus(&dir, &outcome).unwrap();
        assert_eq!(written.len(), 1);
        let corpus = load_corpus(&dir).unwrap();
        assert_eq!(corpus.len(), 1);
        assert_eq!(corpus[0].name, "found-test-r1");
        assert_eq!(corpus[0].fixture, fixture);
        assert_eq!(corpus[0].schedule, schedule);
        assert!(corpus[0].objectives.as_ref().unwrap().bit_eq(&obj));
        // The eval settings ride along, so a replay under them
        // reproduces the stored objectives exactly.
        let stored_eval = corpus[0].eval_options();
        assert_eq!(stored_eval.epoch_ticks, tiny_eval(false).epoch_ticks);
        assert!(!stored_eval.oracle);
        let replay = evaluate(&corpus[0].fixture, &corpus[0].schedule, &stored_eval).unwrap();
        assert!(replay.bit_eq(&obj));
        let _ = fs::remove_dir_all(&dir);
        // Missing directory = empty corpus.
        assert!(load_corpus(&dir).unwrap().is_empty());
    }

    #[test]
    fn run_fuzz_tiny_budget_finds_and_shrinks() {
        let options = FuzzOptions {
            budget: 8,
            seed: 7,
            fixture: tiny_fixture(),
            horizon_ticks: 500,
            thread_budget: 36,
            top_k: 1,
            eval: tiny_eval(false),
            verbose: false,
            ..Default::default()
        };
        let a = run_fuzz(&options).unwrap();
        assert!(!a.found.is_empty(), "no schedule survived the campaign");
        assert!(a.evaluations >= options.budget);
        assert_eq!(a.handwritten.len(), 4);
        assert!(a.handwritten_best_gap > 0.0);
        for f in &a.found {
            f.schedule.validate(options.fixture.nodes).unwrap();
        }
        // Campaigns are deterministic per seed.
        let b = run_fuzz(&options).unwrap();
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.found.len(), b.found.len());
        for (x, y) in a.found.iter().zip(&b.found) {
            assert_eq!(x.schedule, y.schedule);
            assert_eq!(x.fixture, y.fixture);
            assert_eq!(x.eval, y.eval);
            assert!(x.objectives.bit_eq(&y.objectives));
        }
    }

    /// Heterogeneous speed derivation is deterministic, distinct from
    /// the homogeneous pool, and graph-stable: rerolling only the
    /// speed seed never shifts the topology under a candidate.
    #[test]
    fn speed_seed_derives_speeds_without_touching_the_graph() {
        let homo = tiny_fixture();
        let hetero = FuzzFixture { speed_seed: 7, ..homo };
        let (g0, m0, _) = homo.build();
        let (g1, m1, _) = hetero.build();
        let (g2, m2, _) = hetero.build();
        assert_eq!(g0.node_count(), g1.node_count());
        assert_eq!(g0.edge_count(), g1.edge_count(), "speed reroll shifted the graph");
        assert_eq!(m1.count(), m0.count());
        assert_eq!(m1.speeds(), m2.speeds(), "speed derivation is not deterministic");
        assert_ne!(m1.speeds(), m0.speeds(), "speed_seed != 0 must change the pool");
        let total: f64 = m1.speeds().iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "speeds must stay normalized: {total}");
    }

    /// The differential oracle holds on a fully non-default
    /// configuration — heterogeneous machines plus retuned transfer
    /// delays — the integration point config fuzzing exists to stress.
    #[test]
    fn oracle_agrees_on_non_default_configurations() {
        let fixture = FuzzFixture { speed_seed: 41, ..tiny_fixture() };
        let eval = EvalOptions {
            inter_machine_delay: 5,
            intra_machine_delay: 1,
            epoch_ticks: 80,
            ..tiny_eval(true)
        };
        let mut rng = Pcg32::new(17);
        let schedule = tiny_mutator().random_schedule(400, 4, &mut rng);
        let obj = evaluate(&fixture, &schedule, &eval).unwrap();
        assert!(!obj.oracle_divergence, "engine diverged under non-default config");
        assert_eq!(obj.descent_violations, 0, "Thm 4.1 violated: {obj:?}");
        assert!(!obj.rebalanced_truncated, "tiny workload must drain: {obj:?}");
    }

    /// Config mutation keeps every knob inside the search envelope.
    #[test]
    fn mutate_config_stays_in_bounds() {
        let mutator = tiny_mutator();
        let mut rng = Pcg32::new(23);
        let horizon = 500u64;
        let mut fixture = tiny_fixture();
        let mut eval = tiny_eval(false);
        let mut config_changed = 0usize;
        for _ in 0..300 {
            let (f, e) = mutator.mutate_config(&fixture, &eval, horizon, &mut rng);
            if f != fixture || e != eval {
                config_changed += 1;
            }
            fixture = f;
            eval = e;
            assert!(eval.inter_machine_delay <= 9);
            assert!(eval.intra_machine_delay <= eval.inter_machine_delay);
            assert!((40..=horizon).contains(&eval.epoch_ticks));
            assert_eq!(fixture.graph_seed, tiny_fixture().graph_seed);
            assert_eq!(fixture.nodes, tiny_fixture().nodes);
            assert_eq!(fixture.machines, tiny_fixture().machines);
        }
        assert!(config_changed > 200, "mutation arms mostly no-ops: {config_changed}/300");
    }

    /// Pre-config-fuzz corpus JSON (no speed_seed, no delay fields)
    /// parses to the exact configuration those entries were measured
    /// under; wrong-typed fields are clean errors, never silent
    /// defaults.
    #[test]
    fn config_fields_default_for_legacy_json_and_reject_bad_types() {
        let legacy_fixture = FuzzFixture::from_json(
            &parse_json(r#"{"graph_seed":2011,"nodes":96,"machines":4}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(legacy_fixture.speed_seed, 0, "legacy fixtures are homogeneous");
        let bad_fixture = FuzzFixture::from_json(
            &parse_json(r#"{"graph_seed":2011,"nodes":96,"machines":4,"speed_seed":"x"}"#)
                .unwrap(),
        );
        assert!(bad_fixture.is_err(), "string speed_seed must be rejected");

        let legacy_eval = EvalOptions::from_json(
            &parse_json(r#"{"epoch_ticks":120,"framework":"A","max_ticks":200000,"oracle":false}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(legacy_eval.inter_machine_delay, 3, "legacy evals use the engine default");
        assert_eq!(legacy_eval.intra_machine_delay, 0);
        let bad_eval = EvalOptions::from_json(
            &parse_json(
                r#"{"epoch_ticks":120,"framework":"A","inter_machine_delay":"3","max_ticks":200000,"oracle":false}"#,
            )
            .unwrap(),
        );
        assert!(bad_eval.is_err(), "string delay must be rejected");

        // Non-default configs round-trip exactly through JSON.
        let fixture = FuzzFixture { speed_seed: 99, ..FuzzFixture::default() };
        let back = FuzzFixture::from_json(&parse_json(&fixture.to_json().render()).unwrap());
        assert_eq!(back.unwrap(), fixture);
        let eval = EvalOptions {
            inter_machine_delay: 7,
            intra_machine_delay: 2,
            ..EvalOptions::default()
        };
        let back = EvalOptions::from_json(&parse_json(&eval.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, eval);
    }
}

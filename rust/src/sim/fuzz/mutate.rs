//! Genome operators of the fuzz campaign: validity-preserving
//! mutation / crossover / engine-configuration mutation over
//! [`DriftSchedule`] genomes ([`Mutator`]), plus the delta-debug
//! shrinker ([`shrink`]), which re-scores candidates through the
//! parent module's [`evaluate`] oracle.

use crate::sim::scenario::{DriftGene, DriftSchedule, GeneKind, MAX_GENES};
use crate::util::rng::Pcg32;

use super::{evaluate, EvalOptions, FuzzFixture, Objectives};

/// Validity-preserving genome operators: every product of
/// [`Mutator::random_schedule`], [`Mutator::mutate`], and
/// [`Mutator::crossover`] passes `DriftSchedule::validate` for the
/// configured node count (property-tested in `prop_invariants.rs`).
#[derive(Debug, Clone)]
pub struct Mutator {
    /// LP count of the target graph (centers stay in range).
    pub nodes: usize,
    /// Total thread budget every search candidate is normalized to, so
    /// schedules compare like-for-like.
    pub thread_budget: u32,
    /// One refinement epoch, in per-mille of the horizon (the
    /// epoch-align operator snaps windows to this grid).
    pub epoch_pm: u32,
    /// Gene-count cap for search candidates.
    pub max_genes: usize,
}

impl Mutator {
    fn random_gene(&self, rng: &mut Pcg32) -> DriftGene {
        let kind = match rng.index(10) {
            0..=5 => GeneKind::Hotspot,
            6 | 7 => GeneKind::Surge,
            8 => GeneKind::Background,
            _ => GeneKind::Noise,
        };
        let len_pm = 40 + rng.gen_below(400);
        DriftGene {
            kind,
            start_pm: rng.gen_below(1001 - len_pm),
            len_pm,
            center: rng.index(self.nodes.max(1)),
            radius: rng.gen_below(3),
            threads: 1 + rng.gen_below(self.thread_budget.max(2) / 2 + 1),
            hot_pm: 700 + rng.gen_below(301),
        }
    }

    /// A fresh random schedule over `horizon` ticks.
    pub fn random_schedule(&self, horizon: u64, hop_limit: u32, rng: &mut Pcg32) -> DriftSchedule {
        let mut s = DriftSchedule {
            seed: rng.next_u64(),
            horizon_ticks: horizon.max(1),
            hop_limit,
            ts_rate_pm: 500,
            ts_jitter: 8,
            genes: Vec::new(),
        };
        let count = 2 + rng.index(5);
        for _ in 0..count {
            s.genes.push(self.random_gene(rng));
        }
        self.normalize(&mut s);
        s
    }

    /// Apply 1–3 random edits, then restore the schedule invariants.
    pub fn mutate(&self, s: &DriftSchedule, rng: &mut Pcg32) -> DriftSchedule {
        let mut out = s.clone();
        let edits = 1 + rng.index(3);
        for _ in 0..edits {
            self.mutate_once(&mut out, rng);
        }
        self.normalize(&mut out);
        out
    }

    fn mutate_once(&self, s: &mut DriftSchedule, rng: &mut Pcg32) {
        if s.genes.is_empty() {
            s.genes.push(self.random_gene(rng));
            return;
        }
        let i = rng.index(s.genes.len());
        match rng.index(10) {
            // Relocate the region.
            0 => s.genes[i].center = rng.index(self.nodes.max(1)),
            // Concentrate: hotter, tighter.
            1 => {
                let g = &mut s.genes[i];
                g.hot_pm = (g.hot_pm + 100 + rng.gen_below(300)).min(1000);
                g.radius = g.radius.saturating_sub(1);
            }
            // Diffuse: cooler, wider.
            2 => {
                let g = &mut s.genes[i];
                g.hot_pm = g.hot_pm.saturating_sub(100 + rng.gen_below(300));
                g.radius = (g.radius + 1).min(4);
            }
            // Move the window.
            3 => {
                let g = &mut s.genes[i];
                let len = g.len_pm.clamp(1, 1000);
                g.len_pm = len;
                g.start_pm = rng.gen_below(1001 - len);
            }
            // Resize the window.
            4 => {
                let g = &mut s.genes[i];
                let max_len = (1000 - g.start_pm.min(999)).max(1);
                g.len_pm = 1 + rng.gen_below(max_len);
            }
            // Split one gene into consecutive halves.
            5 => {
                if s.genes.len() < self.max_genes {
                    let g = s.genes[i];
                    if g.len_pm >= 2 && g.threads >= 2 {
                        let half = g.len_pm / 2;
                        let mut left = g;
                        left.len_pm = half;
                        left.threads = g.threads / 2;
                        let mut right = g;
                        right.start_pm = g.start_pm + half;
                        right.len_pm = g.len_pm - half;
                        right.threads = g.threads - g.threads / 2;
                        s.genes[i] = left;
                        s.genes.push(right);
                    }
                }
            }
            // Delete a gene; its threads move to a survivor.
            6 => {
                if s.genes.len() > 1 {
                    let removed = s.genes.remove(i);
                    let j = rng.index(s.genes.len());
                    s.genes[j].threads = s.genes[j].threads.saturating_add(removed.threads);
                }
            }
            // Clone a gene to a new window and center (relocation).
            7 => {
                if s.genes.len() < self.max_genes {
                    let mut g = s.genes[i];
                    g.center = rng.index(self.nodes.max(1));
                    let len = g.len_pm.clamp(1, 1000);
                    g.len_pm = len;
                    g.start_pm = rng.gen_below(1001 - len);
                    s.genes.push(g);
                }
            }
            // Snap the window to the refinement-epoch grid (the
            // adversarial phase alignment).
            8 => {
                let g = &mut s.genes[i];
                let step = self.epoch_pm.clamp(1, 1000);
                g.len_pm = step;
                g.start_pm = (g.start_pm.min(999) / step) * step;
                if g.start_pm + g.len_pm > 1000 {
                    g.start_pm = 1000 - g.len_pm;
                }
            }
            // Flip the gene kind.
            _ => s.genes[i].kind = GeneKind::ALL[rng.index(GeneKind::ALL.len())],
        }
    }

    /// Single-cut crossover on the time axis: `a`'s genes before the
    /// cut, `b`'s after.
    pub fn crossover(
        &self,
        a: &DriftSchedule,
        b: &DriftSchedule,
        rng: &mut Pcg32,
    ) -> DriftSchedule {
        let cut = rng.gen_below(1001);
        let mut out = a.clone();
        if rng.chance(0.5) {
            out.seed = b.seed;
        }
        out.genes = a
            .genes
            .iter()
            .filter(|g| g.start_pm < cut)
            .chain(b.genes.iter().filter(|g| g.start_pm >= cut))
            .copied()
            .collect();
        if out.genes.is_empty() {
            out.genes = a.genes.clone();
        }
        self.normalize(&mut out);
        out
    }

    /// Mutate the engine *configuration* a candidate is scored under
    /// rather than its schedule: reroll (or zero) the machine-speed
    /// heterogeneity seed, retune the transfer delays, or rescale the
    /// refinement epoch. One arm per call; every product stays inside
    /// the search envelope (`inter <= 9`, `intra <= inter`,
    /// `epoch_ticks` in `[40, horizon]`). The graph seed, node count
    /// and machine count are deliberately never touched — candidates
    /// keep comparing on the same topology.
    pub fn mutate_config(
        &self,
        fixture: &FuzzFixture,
        eval: &EvalOptions,
        horizon: u64,
        rng: &mut Pcg32,
    ) -> (FuzzFixture, EvalOptions) {
        let mut fixture = *fixture;
        let mut eval = eval.clone();
        match rng.index(4) {
            // Reroll machine speeds; occasionally fall back to the
            // homogeneous pool so the search can retreat from a dead
            // end. `| 1` keeps a reroll distinct from "homogeneous".
            0 => {
                fixture.speed_seed = if fixture.speed_seed != 0 && rng.chance(0.25) {
                    0
                } else {
                    rng.next_u64() | 1
                };
            }
            // Retune the cross-machine transfer delay (0 = free wires,
            // 9 = triple the engine default — straggler-rollback heavy).
            1 => {
                eval.inter_machine_delay = rng.gen_below(10) as u64;
                eval.intra_machine_delay =
                    eval.intra_machine_delay.min(eval.inter_machine_delay);
            }
            // Intra-machine delay never exceeds the cross-machine one.
            2 => {
                eval.intra_machine_delay =
                    rng.gen_below(eval.inter_machine_delay as u32 + 1) as u64;
            }
            // Halve or double the refinement epoch (phase-alignment
            // pathologies live at both extremes).
            _ => {
                let scaled = if rng.chance(0.5) {
                    eval.epoch_ticks.saturating_mul(2)
                } else {
                    eval.epoch_ticks / 2
                };
                eval.epoch_ticks = scaled.clamp(40, horizon.max(40));
            }
        }
        (fixture, eval)
    }

    /// Restore the schedule invariants after an edit: clamp every gene
    /// into range, rebalance thread counts to the shared budget, and
    /// re-sort into monotone start order.
    pub fn normalize(&self, s: &mut DriftSchedule) {
        if s.genes.len() > self.max_genes.min(MAX_GENES) {
            s.genes.truncate(self.max_genes.min(MAX_GENES));
        }
        for g in &mut s.genes {
            if self.nodes > 0 {
                g.center %= self.nodes;
            }
            g.radius = g.radius.min(4);
            g.hot_pm = g.hot_pm.min(1000);
            g.len_pm = g.len_pm.clamp(1, 1000);
            g.start_pm = g.start_pm.min(1000 - g.len_pm);
            g.threads = g.threads.max(1);
        }
        self.rebalance_threads(&mut s.genes);
        s.sort_genes();
    }

    /// Scale gene thread counts so the schedule spends (about) the
    /// shared budget — candidates must compare like-for-like.
    fn rebalance_threads(&self, genes: &mut [DriftGene]) {
        if genes.is_empty() {
            return;
        }
        let budget = self.thread_budget.max(genes.len() as u32);
        let sum: u64 = genes.iter().map(|g| g.threads as u64).sum::<u64>().max(1);
        let mut acc: u32 = 0;
        for g in genes.iter_mut() {
            g.threads = ((g.threads as u64 * budget as u64 / sum) as u32).max(1);
            acc += g.threads;
        }
        if acc != budget {
            let idx = genes
                .iter()
                .enumerate()
                .max_by_key(|(_, g)| g.threads)
                .map(|(i, _)| i)
                .expect("non-empty");
            if acc > budget {
                genes[idx].threads = genes[idx].threads.saturating_sub(acc - budget).max(1);
            } else {
                genes[idx].threads += budget - acc;
            }
        }
    }
}

/// Delta-debug shrink candidates of `s`, each strictly smaller by the
/// lexicographic size metric (gene count, total threads, window sum,
/// radius sum) and each valid whenever `s` is — gene removal keeps the
/// start order, and halving a field never lifts it out of range.
pub fn shrink_steps(s: &DriftSchedule) -> Vec<DriftSchedule> {
    let mut out = Vec::new();
    if s.genes.len() > 1 {
        for i in 0..s.genes.len() {
            let mut c = s.clone();
            c.genes.remove(i);
            out.push(c);
        }
    }
    for i in 0..s.genes.len() {
        let g = s.genes[i];
        if g.threads > 1 {
            let mut c = s.clone();
            c.genes[i].threads = g.threads / 2;
            out.push(c);
        }
        if g.len_pm > 1 {
            let mut c = s.clone();
            c.genes[i].len_pm = (g.len_pm / 2).max(1);
            out.push(c);
        }
        if g.radius > 0 {
            let mut c = s.clone();
            c.genes[i].radius = g.radius - 1;
            out.push(c);
        }
    }
    out
}

/// Delta-debug `schedule` to a (locally) minimal genome that still
/// satisfies the predicate: for bug-class findings the bug must
/// survive; otherwise the score must stay at or above `floor`. Returns
/// the shrunk schedule, its objectives, and the evaluations spent.
pub fn shrink(
    fixture: &FuzzFixture,
    schedule: &DriftSchedule,
    objectives: &Objectives,
    eval: &EvalOptions,
    floor: f64,
    eval_budget: usize,
) -> (DriftSchedule, Objectives, usize) {
    let want_bug = objectives.is_bug();
    let keep = |obj: &Objectives| {
        if want_bug {
            obj.is_bug()
        } else {
            obj.score() >= floor
        }
    };
    let mut best = schedule.clone();
    let mut best_obj = objectives.clone();
    let mut used = 0usize;
    'outer: loop {
        if used >= eval_budget {
            break;
        }
        for candidate in shrink_steps(&best) {
            if used >= eval_budget {
                break 'outer;
            }
            used += 1;
            let Ok(obj) = evaluate(fixture, &candidate, eval) else { continue };
            if keep(&obj) {
                best = candidate;
                best_obj = obj;
                continue 'outer; // restart from the smaller genome
            }
        }
        break; // fixpoint: no candidate preserves the property
    }
    (best, best_obj, used)
}

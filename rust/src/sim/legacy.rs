//! The pre-rewrite (PR 2–6 era) hot path, retained verbatim as the
//! measured **baseline** for the data-oriented rewrite of [`super::lp`]
//! / [`super::engine`] (DESIGN.md §11).
//!
//! `bench_simulator` runs [`LegacyEngine`] and [`super::SimEngine`] on
//! the same fixture and publishes both LP-ticks/s numbers in the
//! `hotpath` group of `results/BENCH_sim.json`, asserting the final
//! [`SimStats`] are equal — so the before/after comparison doubles as a
//! differential test of the rewrite. This module deliberately keeps the
//! old layouts: per-LP `HashMap<ThreadId, SlotIdx>` thread-slot map,
//! `HashSet<ThreadId>` seen-set, per-history-entry `Vec<NodeId>`
//! forward lists, struct-keyed heaps, and the sorted-`Vec` active
//! worklist with a `Vec<bool>` mask. Do not "fix" it — its whole value
//! is staying what the rewrite replaced.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::Barrier;

use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};
use crate::sim::engine::{EpochCounters, Injection, SimOptions, SimStats};
use crate::sim::event::{Event, EventKind, SimTime, ThreadId, WallTime};

#[derive(Debug, Clone)]
struct HistoryEntry {
    event: Event,
    forwarded_to: Vec<NodeId>,
}

#[derive(Debug, Clone, Copy)]
struct Busy {
    event: Event,
    done_at: WallTime,
}

enum StartOutcome {
    Nothing,
    Started { rolled_back: usize, cancellations: Vec<(NodeId, Event)> },
    RolledBack { rolled_back: usize, cancellations: Vec<(NodeId, Event)> },
}

#[inline]
fn kind_rank(kind: EventKind) -> u8 {
    match kind {
        EventKind::Rollback => 0,
        _ => 1,
    }
}

type SlotIdx = u32;

#[derive(Debug, Clone, Default)]
struct Slot {
    gen: u32,
    ev: Option<Event>,
    ready_at: WallTime,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct ReadyKey {
    time: SimTime,
    rank: u8,
    thread: ThreadId,
    slot: SlotIdx,
    gen: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct DelayKey {
    ready_at: WallTime,
    slot: SlotIdx,
    gen: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct TimeKey {
    time: SimTime,
    slot: SlotIdx,
    gen: u32,
}

/// The old pointer-chasing LP: hash-map thread index, hash-set seen
/// filter, per-entry forward `Vec`s.
#[derive(Debug, Clone, Default)]
struct Lp {
    slots: Vec<Slot>,
    free: Vec<SlotIdx>,
    live: usize,
    ready: BinaryHeap<Reverse<ReadyKey>>,
    delayed: BinaryHeap<Reverse<DelayKey>>,
    times: BinaryHeap<Reverse<TimeKey>>,
    thread_slot: HashMap<ThreadId, SlotIdx>,
    seen: HashSet<ThreadId>,
    local_time: SimTime,
    busy: Option<Busy>,
    history: Vec<HistoryEntry>,
    rollbacks: u64,
}

impl Lp {
    fn insert_event(&mut self, ev: Event, now: WallTime) {
        let ready_at = now + ev.tick;
        let ev = Event { tick: 0, ..ev };
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(Slot::default());
                (self.slots.len() - 1) as SlotIdx
            }
        };
        let gen = {
            let s = &mut self.slots[slot as usize];
            s.ev = Some(ev);
            s.ready_at = ready_at;
            s.gen
        };
        if ev.kind != EventKind::Rollback {
            self.thread_slot.entry(ev.thread).or_insert(slot);
        }
        if ready_at <= now {
            self.ready.push(Reverse(ReadyKey {
                time: ev.time,
                rank: kind_rank(ev.kind),
                thread: ev.thread,
                slot,
                gen,
            }));
        } else {
            self.delayed.push(Reverse(DelayKey { ready_at, slot, gen }));
        }
        self.times.push(Reverse(TimeKey { time: ev.time, slot, gen }));
        self.live += 1;
    }

    fn remove_slot(&mut self, slot: SlotIdx) -> Event {
        let s = &mut self.slots[slot as usize];
        let ev = s.ev.take().expect("removing an empty slot");
        s.gen = s.gen.wrapping_add(1);
        self.free.push(slot);
        self.live -= 1;
        if ev.kind != EventKind::Rollback {
            if let Some(&mapped) = self.thread_slot.get(&ev.thread) {
                if mapped == slot {
                    self.thread_slot.remove(&ev.thread);
                }
            }
        }
        ev
    }

    #[inline]
    fn slot_live(&self, slot: SlotIdx, gen: u32) -> bool {
        let s = &self.slots[slot as usize];
        s.gen == gen && s.ev.is_some()
    }

    fn promote(&mut self, now: WallTime) {
        while let Some(&Reverse(key)) = self.delayed.peek() {
            if key.ready_at > now {
                break;
            }
            self.delayed.pop();
            if !self.slot_live(key.slot, key.gen) {
                continue;
            }
            let ev = self.slots[key.slot as usize].ev.expect("live slot has an event");
            self.ready.push(Reverse(ReadyKey {
                time: ev.time,
                rank: kind_rank(ev.kind),
                thread: ev.thread,
                slot: key.slot,
                gen: key.gen,
            }));
        }
    }

    fn peek_ready(&mut self, now: WallTime) -> Option<SlotIdx> {
        self.promote(now);
        while let Some(&Reverse(key)) = self.ready.peek() {
            if self.slot_live(key.slot, key.gen) {
                return Some(key.slot);
            }
            self.ready.pop();
        }
        None
    }

    fn earliest_event_at(&mut self, now: WallTime) -> Option<WallTime> {
        if self.peek_ready(now).is_some() {
            return Some(now);
        }
        while let Some(&Reverse(key)) = self.delayed.peek() {
            if self.slot_live(key.slot, key.gen) {
                return Some(key.ready_at);
            }
            self.delayed.pop();
        }
        None
    }

    fn receive(&mut self, ev: Event, now: WallTime) {
        if ev.kind == EventKind::Rollback {
            if let Some(&slot) = self.thread_slot.get(&ev.thread) {
                self.remove_slot(slot);
                self.seen.remove(&ev.thread);
                return;
            }
        } else {
            self.seen.insert(ev.thread);
        }
        self.insert_event(ev, now);
    }

    fn has_seen(&self, thread: ThreadId) -> bool {
        self.seen.contains(&thread)
    }

    fn rollback_to(
        &mut self,
        horizon: SimTime,
        transfer_delay: WallTime,
        now: WallTime,
    ) -> (usize, Vec<(NodeId, Event)>) {
        let mut cancellations = Vec::new();
        let mut restored = 0;
        let mut kept = Vec::with_capacity(self.history.len());
        for entry in std::mem::take(&mut self.history) {
            if entry.event.time > horizon {
                restored += 1;
                for &nb in &entry.forwarded_to {
                    cancellations.push((nb, entry.event.rollback_for(transfer_delay)));
                }
                self.insert_event(Event { tick: 0, ..entry.event }, now);
            } else {
                kept.push(entry);
            }
        }
        self.history = kept;
        self.local_time = self.local_time.min(horizon);
        if restored > 0 {
            self.rollbacks += 1;
        }
        (restored, cancellations)
    }

    fn process_rollback(
        &mut self,
        ev: Event,
        transfer_delay: WallTime,
        now: WallTime,
    ) -> (usize, Vec<(NodeId, Event)>) {
        if let Some(pos) = self.history.iter().position(|h| h.event.thread == ev.thread) {
            let target_time = self.history[pos].event.time;
            let (restored, cancellations) =
                self.rollback_to(target_time.saturating_sub(1), transfer_delay, now);
            if let Some(&slot) = self.thread_slot.get(&ev.thread) {
                self.remove_slot(slot);
            }
            self.seen.remove(&ev.thread);
            return (restored, cancellations);
        }
        (0, Vec::new())
    }

    fn start_next(
        &mut self,
        now: WallTime,
        occupancy_cost: impl Fn(EventKind) -> WallTime,
        transfer_delay: WallTime,
    ) -> StartOutcome {
        debug_assert!(self.busy.is_none());
        let Some(slot) = self.peek_ready(now) else {
            return StartOutcome::Nothing;
        };
        let ev = self.remove_slot(slot);
        match ev.kind {
            EventKind::Rollback => {
                let (rolled_back, cancellations) = self.process_rollback(ev, transfer_delay, now);
                let cost = occupancy_cost(EventKind::Rollback).max(1);
                self.busy = Some(Busy { event: ev, done_at: now + cost - 1 });
                StartOutcome::RolledBack { rolled_back, cancellations }
            }
            _ => {
                let mut rolled_back = 0;
                let mut cancellations = Vec::new();
                if ev.time < self.local_time {
                    let (r, c) = self.rollback_to(ev.time, transfer_delay, now);
                    rolled_back = r;
                    cancellations = c;
                }
                self.local_time = self.local_time.max(ev.time);
                let cost = occupancy_cost(ev.kind).max(1);
                self.busy = Some(Busy { event: ev, done_at: now + cost - 1 });
                StartOutcome::Started { rolled_back, cancellations }
            }
        }
    }

    fn complete_busy(&mut self, now: WallTime) -> Option<Event> {
        match self.busy {
            Some(b) if b.done_at <= now => {
                self.busy = None;
                Some(b.event)
            }
            _ => None,
        }
    }

    fn retire(&mut self, event: Event, forwarded_to: Vec<NodeId>) {
        debug_assert_ne!(event.kind, EventKind::Rollback);
        self.history.push(HistoryEntry { event, forwarded_to });
    }

    fn fossil_collect(&mut self, gvt: SimTime) {
        self.history.retain(|h| h.event.time >= gvt);
    }

    fn min_pending_time(&mut self) -> Option<SimTime> {
        while let Some(&Reverse(key)) = self.times.peek() {
            if self.slot_live(key.slot, key.gen) {
                return Some(key.time);
            }
            self.times.pop();
        }
        None
    }

    fn gvt_contribution(&mut self) -> Option<SimTime> {
        let busy = self.busy.as_ref().map(|b| b.event.time);
        match (busy, self.min_pending_time()) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, Some(b)) => Some(b),
            (None, None) => None,
        }
    }

    fn idle_and_empty(&self) -> bool {
        self.busy.is_none() && self.live == 0
    }

    fn queue_len(&self) -> usize {
        self.live
    }
}

fn occupancy_cost(
    part: &Partition,
    machines: &MachineConfig,
    options: &SimOptions,
    k: MachineId,
    kind: EventKind,
) -> WallTime {
    let base =
        kind.base_process_time(options.base_process_time, options.rollback_process_time);
    let resident = part.count(k) as f64;
    let speed_scale = machines.speed(k) * machines.count() as f64;
    ((resident * base as f64 / speed_scale).ceil() as WallTime).max(1)
}

fn transfer_delay(part: &Partition, options: &SimOptions, from: NodeId, to: NodeId) -> WallTime {
    if part.machine_of(from) == part.machine_of(to) {
        options.intra_machine_delay
    } else {
        options.inter_machine_delay
    }
}

type OutMsg = (NodeId, Event, NodeId);

struct RawSlice<T>(*mut T);

impl<T> Clone for RawSlice<T> {
    fn clone(&self) -> Self {
        RawSlice(self.0)
    }
}
impl<T> Copy for RawSlice<T> {}
unsafe impl<T: Send> Send for RawSlice<T> {}
unsafe impl<T: Send> Sync for RawSlice<T> {}

impl<T> RawSlice<T> {
    fn new(p: *mut T) -> Self {
        RawSlice(p)
    }
    /// # Safety
    /// Caller must hold exclusive logical ownership of index `i` in the
    /// current phase.
    #[inline]
    unsafe fn get(self, i: usize) -> *mut T {
        self.0.add(i)
    }
    /// # Safety
    /// Caller must guarantee no concurrent `&mut` to index `i`.
    #[inline]
    unsafe fn get_const(self, i: usize) -> *const T {
        self.0.add(i) as *const T
    }
}

struct BarrierGuard<'a> {
    barrier: &'a Barrier,
    remaining: u8,
}

impl<'a> BarrierGuard<'a> {
    fn new(barrier: &'a Barrier, phases: u8) -> Self {
        BarrierGuard { barrier, remaining: phases }
    }

    fn wait(&mut self) {
        self.barrier.wait();
        self.remaining -= 1;
    }
}

impl Drop for BarrierGuard<'_> {
    fn drop(&mut self) {
        for _ in 0..self.remaining {
            self.barrier.wait();
        }
    }
}

#[derive(Default)]
struct WorkerOut {
    cancels: Vec<OutMsg>,
    fwds: Vec<OutMsg>,
    events_processed: u64,
    events_forwarded: u64,
    cross_machine_forwards: u64,
    rollbacks: u64,
    antimessages_sent: u64,
}

#[allow(clippy::too_many_arguments)]
fn worker_phase1(
    tick: WallTime,
    my: &[NodeId],
    graph: &Graph,
    part: &Partition,
    machines: &MachineConfig,
    options: &SimOptions,
    lps: RawSlice<Lp>,
    ev_lp: RawSlice<u64>,
    rb_lp: RawSlice<u64>,
    xf_lp: RawSlice<u64>,
    fw_he: RawSlice<u64>,
    barrier: &Barrier,
) -> WorkerOut {
    let mut out = WorkerOut::default();
    let mut sync = BarrierGuard::new(barrier, 3);
    for &i in my {
        let lp = unsafe { &mut *lps.get(i) };
        if lp.busy.is_some() {
            continue;
        }
        let machine = part.machine_of(i);
        let cost_rollback = occupancy_cost(part, machines, options, machine, EventKind::Rollback);
        let cost_normal =
            occupancy_cost(part, machines, options, machine, EventKind::ProcessForward);
        let outcome = lp.start_next(
            tick,
            |kind| match kind {
                EventKind::Rollback => cost_rollback,
                _ => cost_normal,
            },
            options.inter_machine_delay,
        );
        match outcome {
            StartOutcome::Nothing => {}
            StartOutcome::Started { rolled_back, cancellations }
            | StartOutcome::RolledBack { rolled_back, cancellations } => {
                if rolled_back > 0 {
                    unsafe { *rb_lp.get(i) += 1 };
                    out.rollbacks += 1;
                }
                out.antimessages_sent += cancellations.len() as u64;
                for (nb, ev) in cancellations {
                    let mut ev = ev;
                    ev.tick = transfer_delay(part, options, i, nb);
                    out.cancels.push((nb, ev, i));
                }
            }
        }
    }
    sync.wait();
    let mut completed = Vec::new();
    for &i in my {
        let lp = unsafe { &mut *lps.get(i) };
        if let Some(done) = lp.complete_busy(tick) {
            completed.push((i, done));
        }
    }
    sync.wait();
    let mut retires = Vec::new();
    for &(i, done) in &completed {
        unsafe { *ev_lp.get(i) += 1 };
        out.events_processed += 1;
        if done.kind == EventKind::Rollback {
            continue;
        }
        let mut forwarded_to = Vec::new();
        if done.count > 0 {
            let machine = part.machine_of(i);
            let row = graph.row_offset(i);
            for (slot, &nb) in graph.neighbors(i).iter().enumerate() {
                let nb_seen = unsafe { (*lps.get_const(nb)).has_seen(done.thread) };
                if nb_seen {
                    continue;
                }
                let delay = transfer_delay(part, options, i, nb);
                out.fwds.push((nb, done.forwarded(options.hop_latency, delay), i));
                forwarded_to.push(nb);
                out.events_forwarded += 1;
                unsafe { *fw_he.get(row + slot) += 1 };
                if part.machine_of(nb) != machine {
                    out.cross_machine_forwards += 1;
                    unsafe { *xf_lp.get(i) += 1 };
                }
            }
        }
        retires.push((i, done, forwarded_to));
    }
    sync.wait();
    for (i, done, forwarded_to) in retires {
        let lp = unsafe { &mut *lps.get(i) };
        lp.retire(done, forwarded_to);
    }
    out
}

/// The pre-rewrite engine, frozen. Same semantics and options as
/// [`super::SimEngine`]; only the data layout differs.
pub struct LegacyEngine<'g> {
    graph: &'g Graph,
    machines: MachineConfig,
    part: Partition,
    lps: Vec<Lp>,
    options: SimOptions,
    stats: SimStats,
    gvt: SimTime,
    injections: Vec<Injection>,
    inj_prefix_min: Vec<SimTime>,
    epoch: EpochCounters,
    active: Vec<NodeId>,
    is_active: Vec<bool>,
    newly_active: Vec<NodeId>,
    active_scratch: Vec<NodeId>,
    fossil_cursor: usize,
    outbox_cancel: Vec<OutMsg>,
    outbox_fwd: Vec<OutMsg>,
}

impl<'g> LegacyEngine<'g> {
    pub fn new(
        graph: &'g Graph,
        machines: MachineConfig,
        part: Partition,
        options: SimOptions,
        mut injections: Vec<Injection>,
    ) -> Self {
        assert_eq!(part.node_count(), graph.node_count());
        assert_eq!(part.machine_count(), machines.count());
        injections.sort_by_key(|inj| std::cmp::Reverse(inj.at_tick));
        let mut inj_prefix_min = Vec::with_capacity(injections.len());
        let mut m = SimTime::MAX;
        for inj in &injections {
            m = m.min(inj.event.time);
            inj_prefix_min.push(m);
        }
        LegacyEngine {
            graph,
            lps: vec![Lp::default(); graph.node_count()],
            machines,
            part,
            options,
            stats: SimStats::default(),
            gvt: 0,
            injections,
            inj_prefix_min,
            epoch: EpochCounters::for_graph(graph),
            active: Vec::new(),
            is_active: vec![false; graph.node_count()],
            newly_active: Vec::new(),
            active_scratch: Vec::new(),
            fossil_cursor: 0,
            outbox_cancel: Vec::new(),
            outbox_fwd: Vec::new(),
        }
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    pub fn gvt(&self) -> SimTime {
        self.gvt
    }

    pub fn epoch_counters(&self) -> &EpochCounters {
        &self.epoch
    }

    fn transfer_delay(&self, from: NodeId, to: NodeId) -> WallTime {
        transfer_delay(&self.part, &self.options, from, to)
    }

    fn activate(&mut self, i: NodeId) {
        if !self.is_active[i] {
            self.lps[i].fossil_collect(self.gvt);
            self.is_active[i] = true;
            self.newly_active.push(i);
        }
    }

    fn merge_newly_active(&mut self) {
        if self.newly_active.is_empty() {
            return;
        }
        self.newly_active.sort_unstable();
        self.active_scratch.clear();
        self.active_scratch.reserve(self.active.len() + self.newly_active.len());
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.active.len() && b < self.newly_active.len() {
            if self.active[a] < self.newly_active[b] {
                self.active_scratch.push(self.active[a]);
                a += 1;
            } else {
                self.active_scratch.push(self.newly_active[b]);
                b += 1;
            }
        }
        self.active_scratch.extend_from_slice(&self.active[a..]);
        self.active_scratch.extend_from_slice(&self.newly_active[b..]);
        std::mem::swap(&mut self.active, &mut self.active_scratch);
        self.newly_active.clear();
    }

    fn sweep_inactive(&mut self) {
        let lps = &self.lps;
        let is_active = &mut self.is_active;
        self.active.retain(|&i| {
            if lps[i].idle_and_empty() {
                is_active[i] = false;
                false
            } else {
                true
            }
        });
    }

    fn deliver_injections(&mut self, tick: WallTime) {
        while let Some(inj) = self.injections.last().copied() {
            if inj.at_tick > tick {
                break;
            }
            self.injections.pop();
            self.activate(inj.lp);
            self.lps[inj.lp].receive(inj.event, tick);
        }
    }

    fn injections_time_min(&self) -> Option<SimTime> {
        let len = self.injections.len();
        if len > 0 {
            Some(self.inj_prefix_min[len - 1])
        } else {
            None
        }
    }

    fn compute_gvt(&mut self) -> SimTime {
        let mut gvt = SimTime::MAX;
        let active = std::mem::take(&mut self.active);
        for &i in &active {
            if let Some(t) = self.lps[i].gvt_contribution() {
                gvt = gvt.min(t);
            }
        }
        self.active = active;
        if let Some(t) = self.injections_time_min() {
            gvt = gvt.min(t);
        }
        if gvt == SimTime::MAX {
            self.lps.iter().map(|l| l.local_time).max().unwrap_or(0)
        } else {
            gvt
        }
    }

    pub fn drained(&self) -> bool {
        self.injections.is_empty() && self.active.is_empty() && self.newly_active.is_empty()
    }

    #[allow(clippy::needless_range_loop)] // index loop: `self.lps[i]` needs &mut
    fn fast_forward(&mut self, tick: WallTime, tick_limit: WallTime) -> Option<WallTime> {
        let limit = tick_limit.min(self.options.max_ticks);
        let mut dt = limit.saturating_sub(tick);
        if dt == 0 {
            return None;
        }
        if self.options.trace_every > 0 {
            if tick % self.options.trace_every == 0 {
                return None;
            }
            dt = dt.min(self.options.trace_every - tick % self.options.trace_every);
        }
        if let Some(inj) = self.injections.last() {
            debug_assert!(inj.at_tick > tick, "due injection not delivered");
            dt = dt.min(inj.at_tick - tick);
        }
        for idx in 0..self.active.len() {
            let i = self.active[idx];
            if let Some(b) = self.lps[i].busy {
                if b.done_at <= tick {
                    return None;
                }
                dt = dt.min(b.done_at - tick);
            } else {
                match self.lps[i].earliest_event_at(tick) {
                    Some(t) if t <= tick => return None,
                    Some(t) => dt = dt.min(t - tick),
                    None => {}
                }
            }
        }
        (dt >= 1).then_some(dt)
    }

    fn phase1_sequential(&mut self, tick: WallTime) {
        let active = std::mem::take(&mut self.active);
        for &i in &active {
            if self.lps[i].busy.is_some() {
                continue;
            }
            let machine = self.part.machine_of(i);
            let cost_rollback = occupancy_cost(
                &self.part,
                &self.machines,
                &self.options,
                machine,
                EventKind::Rollback,
            );
            let cost_normal = occupancy_cost(
                &self.part,
                &self.machines,
                &self.options,
                machine,
                EventKind::ProcessForward,
            );
            let outcome = self.lps[i].start_next(
                tick,
                |kind| match kind {
                    EventKind::Rollback => cost_rollback,
                    _ => cost_normal,
                },
                self.options.inter_machine_delay,
            );
            self.note_start_outcome(i, outcome);
        }
        for &i in &active {
            if let Some(done) = self.lps[i].complete_busy(tick) {
                self.note_completion(i, done);
            }
        }
        self.active = active;
    }

    fn note_start_outcome(&mut self, i: NodeId, outcome: StartOutcome) {
        match outcome {
            StartOutcome::Nothing => {}
            StartOutcome::Started { rolled_back, cancellations }
            | StartOutcome::RolledBack { rolled_back, cancellations } => {
                if rolled_back > 0 {
                    self.epoch.rollbacks_by_lp[i] += 1;
                    self.stats.rollbacks += 1;
                }
                self.stats.antimessages_sent += cancellations.len() as u64;
                for (nb, ev) in cancellations {
                    let mut ev = ev;
                    ev.tick = self.transfer_delay(i, nb);
                    self.outbox_cancel.push((nb, ev, i));
                }
            }
        }
    }

    fn note_completion(&mut self, i: NodeId, done: Event) {
        self.stats.events_processed += 1;
        self.epoch.events_by_lp[i] += 1;
        if done.kind == EventKind::Rollback {
            return;
        }
        let graph = self.graph;
        let mut forwarded_to = Vec::new();
        if done.count > 0 {
            let machine = self.part.machine_of(i);
            let row = graph.row_offset(i);
            for (slot, &nb) in graph.neighbors(i).iter().enumerate() {
                if self.lps[nb].has_seen(done.thread) {
                    continue;
                }
                let delay = self.transfer_delay(i, nb);
                self.outbox_fwd.push((nb, done.forwarded(self.options.hop_latency, delay), i));
                forwarded_to.push(nb);
                self.stats.events_forwarded += 1;
                self.epoch.forwards_by_half_edge[row + slot] += 1;
                if self.part.machine_of(nb) != machine {
                    self.stats.cross_machine_forwards += 1;
                    self.epoch.cross_forwards_by_lp[i] += 1;
                }
            }
        }
        self.lps[i].retire(done, forwarded_to);
    }

    fn phase1_parallel(&mut self, tick: WallTime, workers: usize) {
        let mut work: Vec<Vec<NodeId>> = vec![Vec::new(); workers];
        for &i in &self.active {
            work[self.part.machine_of(i) % workers].push(i);
        }
        let graph = self.graph;
        let part = &self.part;
        let machines = &self.machines;
        let options = &self.options;
        let lps = RawSlice::new(self.lps.as_mut_ptr());
        let ev_lp = RawSlice::new(self.epoch.events_by_lp.as_mut_ptr());
        let rb_lp = RawSlice::new(self.epoch.rollbacks_by_lp.as_mut_ptr());
        let xf_lp = RawSlice::new(self.epoch.cross_forwards_by_lp.as_mut_ptr());
        let fw_he = RawSlice::new(self.epoch.forwards_by_half_edge.as_mut_ptr());
        let barrier = Barrier::new(workers);
        let mut outs: Vec<WorkerOut> = Vec::with_capacity(workers);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(workers);
            for my in &work {
                let barrier = &barrier;
                handles.push(s.spawn(move || {
                    worker_phase1(
                        tick, my, graph, part, machines, options, lps, ev_lp, rb_lp, xf_lp,
                        fw_he, barrier,
                    )
                }));
            }
            for h in handles {
                outs.push(h.join().expect("sim worker panicked"));
            }
        });
        for out in &mut outs {
            self.stats.events_processed += out.events_processed;
            self.stats.events_forwarded += out.events_forwarded;
            self.stats.cross_machine_forwards += out.cross_machine_forwards;
            self.stats.rollbacks += out.rollbacks;
            self.stats.antimessages_sent += out.antimessages_sent;
            self.outbox_cancel.append(&mut out.cancels);
            self.outbox_fwd.append(&mut out.fwds);
        }
        self.outbox_cancel.sort_by_key(|&(_, _, from)| from);
        self.outbox_fwd.sort_by_key(|&(_, _, from)| from);
    }

    fn deliver_outboxes(&mut self, tick: WallTime) {
        let mut cancels = std::mem::take(&mut self.outbox_cancel);
        for &(nb, ev, _) in &cancels {
            self.deliver_one(nb, ev, tick);
        }
        cancels.clear();
        self.outbox_cancel = cancels;
        let mut fwds = std::mem::take(&mut self.outbox_fwd);
        for &(nb, ev, _) in &fwds {
            self.deliver_one(nb, ev, tick);
        }
        fwds.clear();
        self.outbox_fwd = fwds;
    }

    fn deliver_one(&mut self, nb: NodeId, ev: Event, tick: WallTime) {
        if ev.kind != EventKind::Rollback && self.lps[nb].has_seen(ev.thread) {
            return;
        }
        self.activate(nb);
        self.lps[nb].receive(ev, tick);
    }

    pub fn step_bounded(&mut self, tick_limit: WallTime) -> bool {
        if self.drained() {
            return false;
        }
        let tick = self.stats.ticks;
        self.deliver_injections(tick);
        self.merge_newly_active();

        if let Some(dt) = self.fast_forward(tick, tick_limit) {
            self.stats.ticks += dt;
            self.epoch.ticks += dt;
            return true;
        }

        let workers = if self.options.parallelism == 0 {
            1
        } else {
            self.options.parallelism.min(self.machines.count())
        };
        if workers > 1 && self.active.len() >= self.options.parallel_min_active {
            self.phase1_parallel(tick, workers);
        } else {
            self.phase1_sequential(tick);
        }

        self.deliver_outboxes(tick);
        self.merge_newly_active();

        self.gvt = self.compute_gvt();
        let active = std::mem::take(&mut self.active);
        for &i in &active {
            self.lps[i].fossil_collect(self.gvt);
        }
        self.active = active;
        self.sweep_inactive();

        const FOSSIL_SWEEP_PER_TICK: usize = 64;
        let n = self.lps.len();
        for _ in 0..FOSSIL_SWEEP_PER_TICK.min(n) {
            let i = self.fossil_cursor;
            self.fossil_cursor = (self.fossil_cursor + 1) % n;
            if !self.is_active[i] && !self.lps[i].history.is_empty() {
                self.lps[i].fossil_collect(self.gvt);
            }
        }

        self.stats.ticks += 1;
        self.epoch.ticks += 1;
        true
    }

    pub fn step(&mut self) -> bool {
        self.step_bounded(self.options.max_ticks)
    }

    pub fn run_to_completion(&mut self) -> SimStats {
        while self.stats.ticks < self.options.max_ticks {
            if !self.step() {
                break;
            }
        }
        if !self.drained() {
            self.stats.truncated = true;
        }
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// The legacy engine must agree with the rewritten engine on a
    /// mixed fixture (floods, stragglers, cross-machine delays) — the
    /// same differential check `bench_simulator` performs at scale.
    #[test]
    fn legacy_matches_rewritten_engine() {
        let mut b = GraphBuilder::with_nodes(12);
        for i in 0..11 {
            b.add_edge(i, i + 1, 1.0);
        }
        b.add_edge(0, 6, 1.0);
        let g = b.build();
        let injections: Vec<Injection> = (0..8)
            .map(|t| Injection {
                at_tick: t,
                lp: (t as usize * 3) % 12,
                event: Event::injection(t + 1, t * 2, 4),
            })
            .collect();
        let machines = MachineConfig::homogeneous(3);
        let assignment: Vec<usize> = (0..12).map(|i| i % 3).collect();
        let part = Partition::from_assignment(&g, 3, assignment.clone());
        let mut old =
            LegacyEngine::new(&g, machines.clone(), part, SimOptions::default(), injections.clone());
        let part = Partition::from_assignment(&g, 3, assignment);
        let mut new =
            crate::sim::SimEngine::new(&g, machines, part, SimOptions::default(), injections);
        let a = old.run_to_completion();
        let b = new.run_to_completion();
        assert_eq!(a, b, "legacy and rewritten engines diverged");
        assert_eq!(old.gvt(), new.gvt());
        assert_eq!(old.epoch_counters(), new.epoch_counters());
    }
}

//! Logical-process state (paper Table II) and per-LP operations.
//!
//! Each LP carries its pending event list, the history of processed
//! events (needed for rollback), its local virtual time, and its busy
//! state. The LP-level operations implemented here are the bodies of the
//! paper's Fig. 4 (`Process_noncausal_event`) and Fig. 5
//! (`Process_rollback_event`), restructured as pure state transitions
//! that *return* the anti-messages to send so the engine owns all
//! message routing.

use std::collections::HashSet;

use crate::graph::NodeId;
use crate::sim::event::{Event, EventKind, SimTime, ThreadId, WallTime};

/// A processed event retained for possible rollback, together with the
/// forwards it generated (so anti-messages can chase them).
#[derive(Debug, Clone)]
pub struct HistoryEntry {
    pub event: Event,
    /// Neighbors this event's processing forwarded the thread to.
    pub forwarded_to: Vec<NodeId>,
}

/// Busy state: the event being processed and ticks remaining.
#[derive(Debug, Clone, Copy)]
pub struct Busy {
    pub event: Event,
    pub remaining: WallTime,
}

/// Outcome of selecting and starting the next event on an LP.
#[derive(Debug)]
pub enum StartOutcome {
    /// Nothing ready (empty list or all events still delayed).
    Nothing,
    /// Started processing a (causal or straggler) event; anti-messages
    /// in `.cancellations` must be delivered by the engine.
    Started { rolled_back: usize, cancellations: Vec<(NodeId, Event)> },
    /// Consumed a rollback anti-message; may itself cascade.
    RolledBack { rolled_back: usize, cancellations: Vec<(NodeId, Event)> },
}

/// One logical process (Table II).
#[derive(Debug, Clone, Default)]
pub struct Lp {
    /// Pending events (`event-list` + parallel columns of Table II).
    pub pending: Vec<Event>,
    /// Processed-event history (`*-history` columns).
    pub history: Vec<HistoryEntry>,
    /// Threads present in `pending` or `history` — the "has it received
    /// this packet yet" test used by the flood-forwarding rule.
    pub seen: HashSet<ThreadId>,
    /// Local virtual time (timestamp of last/current processed event).
    pub local_time: SimTime,
    /// Busy processing state (`status?`, `busy-tick`).
    pub busy: Option<Busy>,
    /// Rollback counter (statistics).
    pub rollbacks: u64,
}

impl Lp {
    /// Enqueue an arriving event. Rollback anti-messages may annihilate
    /// a pending event immediately (standard Time Warp optimization);
    /// everything else just joins the list.
    pub fn receive(&mut self, ev: Event) {
        if ev.kind == EventKind::Rollback {
            // Annihilate in-flight (pending) twin if present.
            if let Some(pos) =
                self.pending.iter().position(|p| p.thread == ev.thread && p.kind != EventKind::Rollback)
            {
                self.pending.swap_remove(pos);
                self.seen.remove(&ev.thread);
                return;
            }
        } else {
            self.seen.insert(ev.thread);
        }
        self.pending.push(ev);
    }

    /// Has this LP seen the thread (pending or processed)? This is the
    /// flood-forwarding filter of Fig. 6.
    pub fn has_seen(&self, thread: ThreadId) -> bool {
        self.seen.contains(&thread)
    }

    /// Index of the ready pending event with the lowest timestamp
    /// (rollbacks win ties so cancellations happen promptly).
    fn next_ready(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in self.pending.iter().enumerate() {
            if !e.ready() {
                continue;
            }
            match best {
                Some(b) => {
                    let eb = &self.pending[b];
                    let earlier = e.time < eb.time
                        || (e.time == eb.time
                            && e.kind == EventKind::Rollback
                            && eb.kind != EventKind::Rollback);
                    if earlier {
                        best = Some(i);
                    }
                }
                None => best = Some(i),
            }
        }
        best
    }

    /// Roll local state back so that all history entries with
    /// `event.time > horizon` return to the pending list; returns the
    /// anti-messages for the forwards those entries had generated.
    /// (Body of Fig. 4's restoration loop.)
    fn rollback_to(&mut self, horizon: SimTime, transfer_delay: WallTime) -> (usize, Vec<(NodeId, Event)>) {
        let mut cancellations = Vec::new();
        let mut restored = 0;
        let mut kept = Vec::with_capacity(self.history.len());
        for entry in self.history.drain(..) {
            if entry.event.time > horizon {
                restored += 1;
                for &nb in &entry.forwarded_to {
                    // Anti-messages match on thread id at the receiver, so
                    // the parent event's own (thread, time) is sufficient.
                    cancellations.push((nb, entry.event.rollback_for(transfer_delay)));
                }
                // The event returns to the pending list to be re-executed.
                self.pending.push(Event { tick: 0, ..entry.event });
            } else {
                kept.push(entry);
            }
        }
        self.history = kept;
        // Local time falls back to the horizon.
        self.local_time = self.local_time.min(horizon);
        if restored > 0 {
            self.rollbacks += 1;
        }
        (restored, cancellations)
    }

    /// Consume a rollback anti-message aimed at `thread` (Fig. 5): if the
    /// thread was already processed, roll back past it and drop it; the
    /// annihilation-in-pending case is handled in [`receive`].
    fn process_rollback(&mut self, ev: Event, transfer_delay: WallTime) -> (usize, Vec<(NodeId, Event)>) {
        // Find the processed instance of this thread.
        if let Some(pos) = self.history.iter().position(|h| h.event.thread == ev.thread) {
            let target_time = self.history[pos].event.time;
            // Undo everything after (and including) the cancelled event.
            let (restored, mut cancellations) =
                self.rollback_to(target_time.saturating_sub(1), transfer_delay);
            // The cancelled thread itself must not be re-executed: drop it
            // from pending (rollback_to restored it) and un-see it.
            if let Some(p) = self
                .pending
                .iter()
                .position(|p| p.thread == ev.thread && p.kind != EventKind::Rollback)
            {
                self.pending.swap_remove(p);
            }
            self.seen.remove(&ev.thread);
            // Cancellations for the dropped event's own forwards were
            // already produced by rollback_to (it was in the restored set).
            return (restored, std::mem::take(&mut cancellations));
        }
        // Late anti-message for a thread we never processed (its twin was
        // annihilated in pending, or never arrived): nothing to do.
        (0, Vec::new())
    }

    /// Select the next ready event and start processing it — the Fig. 6
    /// idle-branch. `occupancy_cost` is the busy time charged for the
    /// event (already scaled by machine occupancy by the engine).
    pub fn start_next(
        &mut self,
        occupancy_cost: impl Fn(EventKind) -> WallTime,
        transfer_delay: WallTime,
    ) -> StartOutcome {
        debug_assert!(self.busy.is_none());
        let Some(idx) = self.next_ready() else {
            return StartOutcome::Nothing;
        };
        let ev = self.pending.swap_remove(idx);
        match ev.kind {
            EventKind::Rollback => {
                let (rolled_back, cancellations) = self.process_rollback(ev, transfer_delay);
                // Rollback handling occupies the LP (synchronization
                // overhead): busy for its base cost.
                self.busy = Some(Busy { event: ev, remaining: occupancy_cost(EventKind::Rollback).max(1) });
                StartOutcome::RolledBack { rolled_back, cancellations }
            }
            _ => {
                let mut rolled_back = 0;
                let mut cancellations = Vec::new();
                if ev.time < self.local_time {
                    // Straggler — Fig. 4 Process_noncausal_event.
                    let (r, c) = self.rollback_to(ev.time, transfer_delay);
                    rolled_back = r;
                    cancellations = c;
                }
                self.local_time = self.local_time.max(ev.time);
                self.busy = Some(Busy { event: ev, remaining: occupancy_cost(ev.kind).max(1) });
                StartOutcome::Started { rolled_back, cancellations }
            }
        }
    }

    /// Advance the busy timer by one tick; returns the completed event
    /// when processing finishes this tick.
    pub fn tick_busy(&mut self) -> Option<Event> {
        let busy = self.busy.as_mut()?;
        busy.remaining -= 1;
        if busy.remaining == 0 {
            let ev = busy.event;
            self.busy = None;
            Some(ev)
        } else {
            None
        }
    }

    /// Record a completed non-rollback event into history together with
    /// the forwards it generated.
    pub fn retire(&mut self, event: Event, forwarded_to: Vec<NodeId>) {
        debug_assert_ne!(event.kind, EventKind::Rollback);
        self.history.push(HistoryEntry { event, forwarded_to });
    }

    /// Decrement transfer-delay ticks of pending events (Fig. 6 epilogue).
    pub fn tick_delays(&mut self) {
        for e in &mut self.pending {
            if e.tick > 0 {
                e.tick -= 1;
            }
        }
    }

    /// Fossil collection (App. B): drop history entries strictly older
    /// than the global virtual time — no rollback can ever reach them.
    pub fn fossil_collect(&mut self, gvt: SimTime) {
        self.history.retain(|h| h.event.time >= gvt);
    }

    /// Lowest timestamp among pending events (regardless of delay), used
    /// in the GVT computation.
    pub fn min_pending_time(&self) -> Option<SimTime> {
        self.pending.iter().map(|e| e.time).min()
    }

    /// Is the LP completely drained?
    pub fn idle_and_empty(&self) -> bool {
        self.busy.is_none() && self.pending.is_empty()
    }

    /// Current queue length (the paper's dynamic node weight b_i, §6.1).
    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(_k: EventKind) -> WallTime {
        2
    }

    #[test]
    fn receive_tracks_seen() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(5, 10, 2));
        assert!(lp.has_seen(5));
        assert!(!lp.has_seen(6));
    }

    #[test]
    fn rollback_annihilates_pending_twin() {
        let mut lp = Lp::default();
        let e = Event::injection(5, 10, 2);
        lp.receive(e);
        lp.receive(e.rollback_for(0));
        assert!(lp.pending.is_empty(), "twin should annihilate");
        assert!(!lp.has_seen(5));
    }

    #[test]
    fn starts_lowest_timestamp_first() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(1, 30, 1));
        lp.receive(Event::injection(2, 10, 1));
        match lp.start_next(cost, 0) {
            StartOutcome::Started { .. } => {}
            other => panic!("expected start, got {other:?}"),
        }
        assert_eq!(lp.busy.unwrap().event.thread, 2);
        assert_eq!(lp.local_time, 10);
    }

    #[test]
    fn delayed_events_not_ready() {
        let mut lp = Lp::default();
        let mut e = Event::injection(1, 5, 1);
        e.tick = 2;
        lp.receive(e);
        assert!(matches!(lp.start_next(cost, 0), StartOutcome::Nothing));
        lp.tick_delays();
        lp.tick_delays();
        assert!(matches!(lp.start_next(cost, 0), StartOutcome::Started { .. }));
    }

    #[test]
    fn busy_ticks_down_and_completes() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(1, 5, 0));
        let _ = lp.start_next(cost, 0);
        assert!(lp.tick_busy().is_none());
        let done = lp.tick_busy().expect("completes after 2 ticks");
        assert_eq!(done.thread, 1);
        assert!(lp.busy.is_none());
    }

    #[test]
    fn straggler_triggers_rollback_and_antimessages() {
        let mut lp = Lp::default();
        // Process event at t=20 that forwarded to neighbor 3.
        lp.local_time = 20;
        lp.seen.insert(9);
        lp.retire(
            Event { thread: 9, time: 20, kind: EventKind::ProcessForward, tick: 0, count: 1 },
            vec![3],
        );
        // Straggler at t=10 arrives.
        lp.receive(Event::injection(4, 10, 0));
        match lp.start_next(cost, 1) {
            StartOutcome::Started { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 1);
                assert_eq!(cancellations.len(), 1);
                assert_eq!(cancellations[0].0, 3);
                assert_eq!(cancellations[0].1.kind, EventKind::Rollback);
                assert_eq!(cancellations[0].1.thread, 9);
            }
            other => panic!("expected Started, got {other:?}"),
        }
        // The rolled-back event is pending again; local time fell back.
        assert!(lp.pending.iter().any(|e| e.thread == 9));
        assert_eq!(lp.local_time, 10);
        assert_eq!(lp.rollbacks, 1);
    }

    #[test]
    fn rollback_event_on_processed_thread_cascades() {
        let mut lp = Lp::default();
        lp.local_time = 30;
        lp.seen.insert(1);
        lp.seen.insert(2);
        lp.retire(
            Event { thread: 1, time: 10, kind: EventKind::ProcessForward, tick: 0, count: 1 },
            vec![7],
        );
        lp.retire(
            Event { thread: 2, time: 20, kind: EventKind::ProcessOnly, tick: 0, count: 0 },
            vec![],
        );
        // Anti-message for thread 1 (t=10): must undo thread 2 as well.
        lp.receive(Event {
            thread: 1,
            time: 10,
            kind: EventKind::Rollback,
            tick: 0,
            count: 0,
        });
        match lp.start_next(cost, 0) {
            StartOutcome::RolledBack { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 2);
                // Thread 1's forward to 7 must be chased.
                assert!(cancellations.iter().any(|(n, e)| *n == 7 && e.thread == 1));
            }
            other => panic!("expected RolledBack, got {other:?}"),
        }
        // Thread 1 is gone (unseen), thread 2 restored to pending.
        assert!(!lp.has_seen(1));
        assert!(lp.pending.iter().any(|e| e.thread == 2));
        assert!(!lp.pending.iter().any(|e| e.thread == 1 && e.kind != EventKind::Rollback));
    }

    #[test]
    fn fossil_collection_drops_old_history() {
        let mut lp = Lp::default();
        for t in [5u64, 10, 15] {
            lp.retire(
                Event { thread: t, time: t, kind: EventKind::ProcessOnly, tick: 0, count: 0 },
                vec![],
            );
        }
        lp.fossil_collect(10);
        assert_eq!(lp.history.len(), 2);
        assert!(lp.history.iter().all(|h| h.event.time >= 10));
    }

    #[test]
    fn late_antimessage_is_harmless() {
        let mut lp = Lp::default();
        lp.receive(Event {
            thread: 42,
            time: 5,
            kind: EventKind::Rollback,
            tick: 0,
            count: 0,
        });
        match lp.start_next(cost, 0) {
            StartOutcome::RolledBack { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 0);
                assert!(cancellations.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn min_pending_time_and_drain() {
        let mut lp = Lp::default();
        assert!(lp.idle_and_empty());
        lp.receive(Event::injection(1, 9, 0));
        lp.receive(Event::injection(2, 4, 0));
        assert_eq!(lp.min_pending_time(), Some(4));
        assert!(!lp.idle_and_empty());
    }
}

//! Logical-process state (paper Table II) and per-LP operations.
//!
//! Each LP carries its pending event set, the history of processed
//! events (needed for rollback), its local virtual time, and its busy
//! state. The LP-level operations implemented here are the bodies of the
//! paper's Fig. 4 (`Process_noncausal_event`) and Fig. 5
//! (`Process_rollback_event`), restructured as pure state transitions
//! that *return* the anti-messages to send so the engine owns all
//! message routing.
//!
//! # Data-oriented layout (DESIGN.md §11)
//!
//! Thread ids are compact (scenario compilation numbers floods
//! `1..=total_threads`), so everything previously keyed by a hash of the
//! thread id is a dense array index instead:
//!
//! * the **per-thread slot map** (annihilation index: the pending
//!   non-rollback twin of a thread) is a `Vec<SlotIdx>` with a
//!   `NO_SLOT` sentinel — one bounds-checked load instead of a
//!   `HashMap` probe;
//! * the **seen set** (the "has it received this packet yet"
//!   flood-forwarding filter of Fig. 6) is a `u64` bitset — `has_seen`
//!   is the hottest read in the engine's fan-out phase;
//! * both grow on demand and can be pre-sized once via
//!   [`Lp::reserve_threads`] (the engine primes them with the maximum
//!   injected thread id on first activation), which is what makes the
//!   steady-state tick loop allocation-free (`alloc_steadystate.rs`);
//! * **forward lists** live in a per-LP append-only arena
//!   (`fwd_arena`): a history entry stores an `(offset, len)` span
//!   instead of owning a `Vec<NodeId>`, so retiring an event copies a
//!   few `usize`s into one growable buffer instead of allocating. Dead
//!   spans (rollback or fossil collection) are reclaimed by an
//!   amortized in-place compaction that slides live spans down
//!   (history offsets are monotone, so `copy_within` never overlaps
//!   wrongly);
//! * **heap keys are packed integers**: the ready heap orders by
//!   `((time << 1) | kind-rank, thread, (gen << 32) | slot)` — the same
//!   total order as the old `(time, rank, thread, slot, gen)` struct
//!   key, compared word-by-word with no padding.
//!
//! # Indexed pending structure
//!
//! * events live in a **slot slab** (`slots` + free list + per-slot
//!   generation counters), so heap entries can reference them stably;
//! * a **ready-min heap** keyed `(time, kind-rank, thread)` yields the
//!   next event to execute (rollbacks win ties so cancellations happen
//!   promptly; the thread id makes selection a total order, independent
//!   of arrival order — required for the deterministic parallel tick);
//! * a **delayed heap** keyed by absolute ready wall-tick replaces the
//!   per-tick transfer-delay countdown: an event received at wall tick
//!   `now` with transfer delay `d` becomes ready at `now + d`, and is
//!   promoted into the ready heap lazily — no per-tick work at all for
//!   in-flight events, which is also what makes the engine's tick
//!   fast-forward O(1) per skipped tick;
//! * the minimum pending timestamp (the LP's GVT contribution) comes
//!   from a third lazy min-heap keyed by event time — amortized
//!   O(log queue) even when the minimum itself is removed.
//!
//! Heap entries are invalidated lazily: removing an event bumps its
//! slot's generation, and stale heap entries are discarded on pop.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::NodeId;
use crate::sim::event::{Event, EventKind, SimTime, ThreadId, WallTime};

/// Busy state: the event being processed and the wall tick during whose
/// phase-completion pass it finishes (absolute, not a countdown).
#[derive(Debug, Clone, Copy)]
pub struct Busy {
    pub event: Event,
    /// Completion wall tick: a cost-`c` event started during tick `t`
    /// completes during tick `t + c - 1` (a cost-1 event completes the
    /// same tick it starts, as in the countdown formulation).
    pub done_at: WallTime,
}

/// Outcome of selecting and starting the next event on an LP.
#[derive(Debug)]
pub enum StartOutcome {
    /// Nothing ready (empty list or all events still delayed).
    Nothing,
    /// Started processing a (causal or straggler) event; anti-messages
    /// in `.cancellations` must be delivered by the engine.
    Started { rolled_back: usize, cancellations: Vec<(NodeId, Event)> },
    /// Consumed a rollback anti-message; may itself cascade.
    RolledBack { rolled_back: usize, cancellations: Vec<(NodeId, Event)> },
}

type SlotIdx = u32;

/// Sentinel of the dense per-thread slot map: "no pending twin".
const NO_SLOT: SlotIdx = SlotIdx::MAX;

/// One slab slot. `gen` increments every time the slot is vacated, so
/// stale heap entries (which carry the generation they were pushed
/// under) can be recognized and discarded.
#[derive(Debug, Clone, Default)]
struct Slot {
    gen: u32,
    ev: Option<Event>,
    /// Absolute wall tick at which the event becomes processable.
    ready_at: WallTime,
}

/// Ready-heap key `((time << 1) | kind-rank, thread, (gen << 32) | slot)`:
/// total order `(time, kind-rank, thread)`; the packed slot word only
/// breaks ties between byte-identical duplicate events.
type ReadyKey = (u64, ThreadId, u64);

/// Delayed-heap key: `(absolute readiness tick, packed slot)`.
type DelayKey = (WallTime, u64);

/// Time-heap key: `(event timestamp, packed slot)` (GVT contribution).
type TimeKey = (SimTime, u64);

/// Pack `(time, kind)` into the ready-heap major word. Times stay far
/// below 2^63 (they grow by hop latencies from injection timestamps),
/// so the shift is lossless; rollbacks rank 0 and win ties.
#[inline]
fn pack_tr(time: SimTime, kind: EventKind) -> u64 {
    debug_assert!(time < (1 << 63), "event time overflows the packed heap key");
    (time << 1) | kind.rank() as u64
}

/// Pack `(slot, gen)` into one word ordered by generation then slot —
/// any total order works here (ties are byte-identical duplicates; see
/// `ReadyKey`), packing just makes the compare one instruction.
#[inline]
fn pack_slot(slot: SlotIdx, gen: u32) -> u64 {
    ((gen as u64) << 32) | slot as u64
}

#[inline]
fn unpack_slot(packed: u64) -> (SlotIdx, u32) {
    (packed as u32, (packed >> 32) as u32)
}

/// A processed event retained for rollback; its forward list is the
/// arena span `fwd_arena[off .. off + len]`.
#[derive(Debug, Clone, Copy)]
struct HistorySpan {
    event: Event,
    off: u32,
    len: u32,
}

/// One logical process (Table II).
#[derive(Debug, Clone, Default)]
pub struct Lp {
    /// Slot slab holding the pending events.
    slots: Vec<Slot>,
    /// Vacant slot indices.
    free: Vec<SlotIdx>,
    /// Number of live pending events.
    live: usize,
    /// Ready events, min-first by `(time, kind-rank, thread)`. Lazy.
    ready: BinaryHeap<Reverse<ReadyKey>>,
    /// Not-yet-ready events, min-first by absolute ready tick. Lazy.
    delayed: BinaryHeap<Reverse<DelayKey>>,
    /// All live events, min-first by timestamp — the LP's GVT
    /// contribution. Lazy (stale entries popped on query), so removing
    /// the current minimum costs O(log q), not a slab rescan.
    times: BinaryHeap<Reverse<TimeKey>>,
    /// Dense per-thread pending-twin slot (annihilation index),
    /// `NO_SLOT` = none. Indexed by thread id.
    thread_slot: Vec<SlotIdx>,
    /// Seen-set bitset, bit `t` = thread `t` present in pending or
    /// history — the flood-forwarding filter.
    seen_words: Vec<u64>,
    /// Local virtual time (timestamp of last/current processed event).
    pub local_time: SimTime,
    /// Busy processing state (`status?`, absolute completion tick).
    pub busy: Option<Busy>,
    /// Processed-event history (`*-history` columns) as arena spans.
    history: Vec<HistorySpan>,
    /// Append-only forward-list arena the history spans point into.
    fwd_arena: Vec<NodeId>,
    /// Arena entries still referenced by a history span (compaction
    /// trigger: compact when at least half the arena is garbage).
    arena_live: usize,
    /// Rollback counter (statistics).
    pub rollbacks: u64,
}

impl Lp {
    /// Pre-size the dense per-thread structures for thread ids
    /// `< bound`. Idempotent and monotone; the engine calls this on
    /// first activation with the maximum injected thread id, so the
    /// steady-state hot path never grows them.
    pub fn reserve_threads(&mut self, bound: usize) {
        if self.thread_slot.len() < bound {
            self.thread_slot.resize(bound, NO_SLOT);
        }
        let words = bound.div_ceil(64);
        if self.seen_words.len() < words {
            self.seen_words.resize(words, 0);
        }
    }

    /// Grow the dense thread structures to cover `thread` (fallback for
    /// ids beyond any [`Self::reserve_threads`] bound).
    #[inline]
    fn ensure_thread(&mut self, thread: ThreadId) {
        let ti = thread as usize;
        if ti >= self.thread_slot.len() {
            self.thread_slot.resize(ti + 1, NO_SLOT);
        }
        let wi = ti / 64;
        if wi >= self.seen_words.len() {
            self.seen_words.resize(wi + 1, 0);
        }
    }

    /// Pending non-rollback twin of `thread`, if any.
    #[inline]
    fn twin_slot(&self, thread: ThreadId) -> Option<SlotIdx> {
        self.thread_slot.get(thread as usize).copied().filter(|&s| s != NO_SLOT)
    }

    /// Has this LP seen the thread (pending or processed)? This is the
    /// flood-forwarding filter of Fig. 6 — the hottest read of the
    /// engine's fan-out phase, one bounds check + one bit test.
    #[inline]
    pub fn has_seen(&self, thread: ThreadId) -> bool {
        let ti = thread as usize;
        match self.seen_words.get(ti / 64) {
            Some(&w) => (w >> (ti % 64)) & 1 == 1,
            None => false,
        }
    }

    /// Mark a thread seen (pending or processed). Public for snapshot
    /// restore and tests; the hot path goes through [`Self::receive`].
    #[inline]
    pub fn mark_seen(&mut self, thread: ThreadId) {
        self.ensure_thread(thread);
        self.seen_words[thread as usize / 64] |= 1 << (thread % 64);
    }

    #[inline]
    fn unmark_seen(&mut self, thread: ThreadId) {
        let ti = thread as usize;
        if let Some(w) = self.seen_words.get_mut(ti / 64) {
            *w &= !(1 << (ti % 64));
        }
    }

    /// Seen threads in ascending order (snapshot capture).
    pub fn seen_threads(&self) -> impl Iterator<Item = ThreadId> + '_ {
        self.seen_words.iter().enumerate().flat_map(|(wi, &word)| {
            let mut bits = word;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as u64;
                    bits &= bits - 1;
                    Some((wi as u64) * 64 + b)
                }
            })
        })
    }

    /// Insert an event into the slab and the appropriate heap. The
    /// event's relative `tick` delay is converted to an absolute ready
    /// tick against `now` and then cleared.
    fn insert_event(&mut self, ev: Event, now: WallTime) {
        let ready_at = now + ev.tick;
        self.insert_event_at(ev, ready_at, now);
    }

    /// Insert an event with an explicit absolute ready tick (snapshot
    /// restore path: `ready_at` may be in the past when the LP was busy
    /// while the event sat ready). The event's relative `tick` must
    /// already be folded into `ready_at`; it is cleared on insertion.
    fn insert_event_at(&mut self, ev: Event, ready_at: WallTime, now: WallTime) {
        let ev = Event { tick: 0, ..ev };
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(Slot::default());
                (self.slots.len() - 1) as SlotIdx
            }
        };
        let gen = {
            let s = &mut self.slots[slot as usize];
            debug_assert!(s.ev.is_none(), "allocated an occupied slot");
            s.ev = Some(ev);
            s.ready_at = ready_at;
            s.gen
        };
        if ev.kind != EventKind::Rollback {
            // At most one live non-rollback event per thread is the
            // steady-state invariant (the flood filter guarantees it for
            // forwards); duplicate *injections* of one thread id are
            // tolerated by keeping the first mapping, so an anti-message
            // annihilates the older twin — matching the linear-scan
            // reference stepper.
            self.ensure_thread(ev.thread);
            let entry = &mut self.thread_slot[ev.thread as usize];
            if *entry == NO_SLOT {
                *entry = slot;
            }
        }
        if ready_at <= now {
            self.ready.push(Reverse((pack_tr(ev.time, ev.kind), ev.thread, pack_slot(slot, gen))));
        } else {
            self.delayed.push(Reverse((ready_at, pack_slot(slot, gen))));
        }
        self.times.push(Reverse((ev.time, pack_slot(slot, gen))));
        self.live += 1;
    }

    /// Vacate a slot, maintaining the thread map. Stale heap entries
    /// are left behind (generation bump invalidates them).
    fn remove_slot(&mut self, slot: SlotIdx) -> Event {
        let s = &mut self.slots[slot as usize];
        let ev = s.ev.take().expect("removing an empty slot");
        s.gen = s.gen.wrapping_add(1);
        self.free.push(slot);
        self.live -= 1;
        if ev.kind != EventKind::Rollback {
            if let Some(entry) = self.thread_slot.get_mut(ev.thread as usize) {
                if *entry == slot {
                    *entry = NO_SLOT;
                }
            }
        }
        ev
    }

    /// True if the heap entry still refers to the event it was pushed
    /// for.
    #[inline]
    fn slot_live(&self, slot: SlotIdx, gen: u32) -> bool {
        let s = &self.slots[slot as usize];
        s.gen == gen && s.ev.is_some()
    }

    /// Move events whose ready tick has arrived into the ready heap.
    fn promote(&mut self, now: WallTime) {
        while let Some(&Reverse((ready_at, packed))) = self.delayed.peek() {
            if ready_at > now {
                break;
            }
            self.delayed.pop();
            let (slot, gen) = unpack_slot(packed);
            if !self.slot_live(slot, gen) {
                continue;
            }
            let s = &self.slots[slot as usize];
            debug_assert_eq!(s.ready_at, ready_at);
            let ev = s.ev.expect("live slot has an event");
            self.ready.push(Reverse((pack_tr(ev.time, ev.kind), ev.thread, packed)));
        }
    }

    /// Slot of the ready pending event with the lowest
    /// `(time, kind-rank, thread)` key, discarding stale heap entries.
    fn peek_ready(&mut self, now: WallTime) -> Option<SlotIdx> {
        self.promote(now);
        while let Some(&Reverse((_, _, packed))) = self.ready.peek() {
            let (slot, gen) = unpack_slot(packed);
            if self.slot_live(slot, gen) {
                return Some(slot);
            }
            self.ready.pop();
        }
        None
    }

    /// Earliest wall tick at which this LP has (or will have) a
    /// processable event, given it stays unperturbed: `Some(now)` if an
    /// event is ready, the delayed minimum otherwise. Drives the
    /// engine's tick fast-forward.
    pub fn earliest_event_at(&mut self, now: WallTime) -> Option<WallTime> {
        if self.peek_ready(now).is_some() {
            return Some(now);
        }
        while let Some(&Reverse((ready_at, packed))) = self.delayed.peek() {
            let (slot, gen) = unpack_slot(packed);
            if self.slot_live(slot, gen) {
                return Some(ready_at);
            }
            self.delayed.pop();
        }
        None
    }

    /// Enqueue an arriving event at wall tick `now`. Rollback
    /// anti-messages may annihilate a pending event immediately
    /// (standard Time Warp optimization); everything else joins the
    /// pending set, becoming ready `ev.tick` ticks from now.
    pub fn receive(&mut self, ev: Event, now: WallTime) {
        if ev.kind == EventKind::Rollback {
            // Annihilate the in-flight (pending) twin if present.
            if let Some(slot) = self.twin_slot(ev.thread) {
                self.remove_slot(slot);
                self.unmark_seen(ev.thread);
                return;
            }
        } else {
            self.mark_seen(ev.thread);
        }
        self.insert_event(ev, now);
    }

    /// Roll local state back so that all history entries with
    /// `event.time > horizon` return to the pending set; appends the
    /// anti-messages for the forwards those entries had generated.
    /// (Body of Fig. 4's restoration loop.) Compacts `history` in
    /// place; abandoned arena spans are reclaimed lazily.
    fn rollback_to(
        &mut self,
        horizon: SimTime,
        transfer_delay: WallTime,
        now: WallTime,
        cancellations: &mut Vec<(NodeId, Event)>,
    ) -> usize {
        let mut restored = 0;
        let mut w = 0;
        for r in 0..self.history.len() {
            let h = self.history[r];
            if h.event.time > horizon {
                restored += 1;
                let start = h.off as usize;
                for idx in start..start + h.len as usize {
                    // Anti-messages match on thread id at the receiver, so
                    // the parent event's own (thread, time) is sufficient.
                    cancellations
                        .push((self.fwd_arena[idx], h.event.rollback_for(transfer_delay)));
                }
                self.arena_live -= h.len as usize;
                // The event returns to the pending set to be re-executed
                // immediately (no transfer delay: it is already local).
                self.insert_event(Event { tick: 0, ..h.event }, now);
            } else {
                self.history[w] = h;
                w += 1;
            }
        }
        self.history.truncate(w);
        // Local time falls back to the horizon.
        self.local_time = self.local_time.min(horizon);
        if restored > 0 {
            self.rollbacks += 1;
        }
        restored
    }

    /// Consume a rollback anti-message aimed at `thread` (Fig. 5): if the
    /// thread was already processed, roll back past it and drop it; the
    /// annihilation-in-pending case is handled in [`Self::receive`].
    fn process_rollback(
        &mut self,
        ev: Event,
        transfer_delay: WallTime,
        now: WallTime,
    ) -> (usize, Vec<(NodeId, Event)>) {
        // Find the processed instance of this thread.
        if let Some(pos) = self.history.iter().position(|h| h.event.thread == ev.thread) {
            let target_time = self.history[pos].event.time;
            // Undo everything after (and including) the cancelled event.
            let mut cancellations = Vec::new();
            let restored = self.rollback_to(
                target_time.saturating_sub(1),
                transfer_delay,
                now,
                &mut cancellations,
            );
            // The cancelled thread itself must not be re-executed: drop it
            // from pending (rollback_to restored it) and un-see it.
            if let Some(slot) = self.twin_slot(ev.thread) {
                self.remove_slot(slot);
            }
            self.unmark_seen(ev.thread);
            // Cancellations for the dropped event's own forwards were
            // already produced by rollback_to (it was in the restored set).
            return (restored, cancellations);
        }
        // Late anti-message for a thread we never processed (its twin was
        // annihilated in pending, or never arrived): nothing to do.
        (0, Vec::new())
    }

    /// Select the next ready event and start processing it — the Fig. 6
    /// idle-branch, at wall tick `now`. `occupancy_cost` is the busy
    /// time charged for the event (already scaled by machine occupancy
    /// by the engine).
    pub fn start_next(
        &mut self,
        now: WallTime,
        occupancy_cost: impl Fn(EventKind) -> WallTime,
        transfer_delay: WallTime,
    ) -> StartOutcome {
        debug_assert!(self.busy.is_none());
        let Some(slot) = self.peek_ready(now) else {
            return StartOutcome::Nothing;
        };
        let ev = self.remove_slot(slot);
        match ev.kind {
            EventKind::Rollback => {
                let (rolled_back, cancellations) = self.process_rollback(ev, transfer_delay, now);
                // Rollback handling occupies the LP (synchronization
                // overhead): busy for its base cost.
                let cost = occupancy_cost(EventKind::Rollback).max(1);
                self.busy = Some(Busy { event: ev, done_at: now + cost - 1 });
                StartOutcome::RolledBack { rolled_back, cancellations }
            }
            _ => {
                let mut rolled_back = 0;
                let mut cancellations = Vec::new();
                if ev.time < self.local_time {
                    // Straggler — Fig. 4 Process_noncausal_event.
                    rolled_back =
                        self.rollback_to(ev.time, transfer_delay, now, &mut cancellations);
                }
                self.local_time = self.local_time.max(ev.time);
                let cost = occupancy_cost(ev.kind).max(1);
                self.busy = Some(Busy { event: ev, done_at: now + cost - 1 });
                StartOutcome::Started { rolled_back, cancellations }
            }
        }
    }

    /// Completion check for wall tick `now`: returns the processed event
    /// when the busy period ends this tick (replaces the per-tick
    /// countdown of the naive formulation).
    pub fn complete_busy(&mut self, now: WallTime) -> Option<Event> {
        match self.busy {
            Some(b) if b.done_at <= now => {
                self.busy = None;
                Some(b.event)
            }
            _ => None,
        }
    }

    /// Record a completed non-rollback event into history together with
    /// the forwards it generated. The forward list is copied into the
    /// arena — no per-event allocation on the send path (the caller
    /// reuses one scratch buffer across events).
    pub fn retire(&mut self, event: Event, forwarded_to: &[NodeId]) {
        debug_assert_ne!(event.kind, EventKind::Rollback);
        debug_assert!(self.fwd_arena.len() + forwarded_to.len() <= u32::MAX as usize);
        let off = self.fwd_arena.len() as u32;
        self.fwd_arena.extend_from_slice(forwarded_to);
        self.arena_live += forwarded_to.len();
        self.history.push(HistorySpan { event, off, len: forwarded_to.len() as u32 });
    }

    /// Fossil collection (App. B): drop history entries strictly older
    /// than the global virtual time — no rollback can ever reach them.
    /// Engines may defer this on idle LPs and catch up on reactivation.
    pub fn fossil_collect(&mut self, gvt: SimTime) {
        let mut w = 0;
        for r in 0..self.history.len() {
            let h = self.history[r];
            if h.event.time >= gvt {
                self.history[w] = h;
                w += 1;
            } else {
                self.arena_live -= h.len as usize;
            }
        }
        self.history.truncate(w);
        self.maybe_compact_arena();
    }

    /// Slide live spans to the front of the arena once at least half of
    /// it is garbage (dead spans from rollbacks / fossil collection).
    /// History offsets are strictly increasing, so every `copy_within`
    /// moves a span left onto garbage or onto itself — in place, no
    /// allocation, amortized O(1) per retired forward.
    fn maybe_compact_arena(&mut self) {
        let len = self.fwd_arena.len();
        if len <= 64 || len <= 2 * self.arena_live {
            return;
        }
        let mut w = 0usize;
        for h in self.history.iter_mut() {
            let start = h.off as usize;
            let span_len = h.len as usize;
            debug_assert!(w <= start);
            self.fwd_arena.copy_within(start..start + span_len, w);
            h.off = w as u32;
            w += span_len;
        }
        debug_assert_eq!(w, self.arena_live);
        self.fwd_arena.truncate(w);
    }

    /// Number of retained history entries.
    pub fn history_len(&self) -> usize {
        self.history.len()
    }

    /// Fast emptiness check for the engine's background fossil sweep.
    #[inline]
    pub fn history_is_empty(&self) -> bool {
        self.history.is_empty()
    }

    /// Iterate retained history entries in retirement order, each with
    /// its forward list resolved from the arena (snapshot capture).
    pub fn history_entries(&self) -> impl Iterator<Item = (Event, &[NodeId])> + '_ {
        self.history
            .iter()
            .map(|h| (h.event, &self.fwd_arena[h.off as usize..(h.off + h.len) as usize]))
    }

    /// Rebuild history from `(event, forward list)` pairs in retirement
    /// order (snapshot restore).
    pub fn restore_history(&mut self, entries: impl IntoIterator<Item = (Event, Vec<NodeId>)>) {
        debug_assert!(self.history.is_empty() && self.fwd_arena.is_empty());
        for (event, forwarded_to) in entries {
            self.retire(event, &forwarded_to);
        }
    }

    /// Lowest timestamp among pending events (regardless of delay), used
    /// in the GVT computation. Amortized O(log q) (lazy stale pops).
    pub fn min_pending_time(&mut self) -> Option<SimTime> {
        while let Some(&Reverse((time, packed))) = self.times.peek() {
            let (slot, gen) = unpack_slot(packed);
            if self.slot_live(slot, gen) {
                return Some(time);
            }
            self.times.pop();
        }
        None
    }

    /// This LP's GVT contribution: the minimum of its busy event's
    /// timestamp and its minimum pending timestamp.
    pub fn gvt_contribution(&mut self) -> Option<SimTime> {
        let busy = self.busy.as_ref().map(|b| b.event.time);
        match (busy, self.min_pending_time()) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, Some(b)) => Some(b),
            (None, None) => None,
        }
    }

    /// Is the LP completely drained?
    pub fn idle_and_empty(&self) -> bool {
        self.busy.is_none() && self.live == 0
    }

    /// Current queue length (the paper's dynamic node weight b_i, §6.1).
    pub fn queue_len(&self) -> usize {
        self.live
    }

    /// Iterate the live pending events (arbitrary order).
    pub fn pending_events(&self) -> impl Iterator<Item = &Event> {
        self.slots.iter().filter_map(|s| s.ev.as_ref())
    }

    /// Iterate the live pending events together with their absolute
    /// ready wall tick (arbitrary order). Snapshot capture sorts these
    /// into the canonical `(time, kind-rank, thread, count, ready_at)`
    /// order before serializing, so the index layout (slots, heap entry
    /// order, generations) never leaks into the snapshot bytes.
    pub fn pending_with_ready_at(&self) -> impl Iterator<Item = (Event, WallTime)> + '_ {
        self.slots.iter().filter_map(|s| s.ev.map(|ev| (ev, s.ready_at)))
    }

    /// Rebuild the pending set from `(event, absolute ready tick)` pairs
    /// at wall tick `now` (snapshot restore). The LP must be freshly
    /// constructed: the slab is rebuilt from scratch so heap keys and
    /// the per-thread annihilation map are re-derived deterministically
    /// from the insertion order (callers pass the canonical sorted
    /// order).
    pub fn restore_pending(
        &mut self,
        events: impl IntoIterator<Item = (Event, WallTime)>,
        now: WallTime,
    ) {
        assert!(self.live == 0 && self.slots.is_empty(), "restore into a non-empty pending set");
        for (ev, ready_at) in events {
            self.insert_event_at(ev, ready_at, now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(_k: EventKind) -> WallTime {
        2
    }

    /// Collect pending events sorted for comparisons.
    fn pending_of(lp: &Lp) -> Vec<Event> {
        let mut v: Vec<Event> = lp.pending_events().copied().collect();
        v.sort_by_key(|e| (e.time, e.kind.rank(), e.thread));
        v
    }

    #[test]
    fn receive_tracks_seen() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(5, 10, 2), 0);
        assert!(lp.has_seen(5));
        assert!(!lp.has_seen(6));
        assert!(!lp.has_seen(1_000_000), "out-of-range thread is unseen");
        assert_eq!(lp.queue_len(), 1);
    }

    #[test]
    fn rollback_annihilates_pending_twin() {
        let mut lp = Lp::default();
        let e = Event::injection(5, 10, 2);
        lp.receive(e, 0);
        lp.receive(e.rollback_for(0), 0);
        assert_eq!(lp.queue_len(), 0, "twin should annihilate");
        assert!(!lp.has_seen(5));
        assert!(lp.idle_and_empty());
    }

    #[test]
    fn annihilation_finds_delayed_twin() {
        let mut lp = Lp::default();
        let mut e = Event::injection(5, 10, 2);
        e.tick = 7; // still in flight
        lp.receive(e, 3);
        assert_eq!(lp.queue_len(), 1);
        lp.receive(e.rollback_for(0), 4);
        assert_eq!(lp.queue_len(), 0);
        assert!(!lp.has_seen(5));
    }

    #[test]
    fn starts_lowest_timestamp_first() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(1, 30, 1), 0);
        lp.receive(Event::injection(2, 10, 1), 0);
        match lp.start_next(0, cost, 0) {
            StartOutcome::Started { .. } => {}
            other => panic!("expected start, got {other:?}"),
        }
        assert_eq!(lp.busy.unwrap().event.thread, 2);
        assert_eq!(lp.local_time, 10);
    }

    #[test]
    fn equal_time_ties_break_on_kind_then_thread() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(9, 10, 1), 0);
        lp.receive(Event::injection(3, 10, 1), 0);
        // Anti-message for an unrelated thread at the same timestamp.
        lp.receive(
            Event { thread: 7, time: 10, kind: EventKind::Rollback, tick: 0, count: 0 },
            0,
        );
        match lp.start_next(0, cost, 0) {
            StartOutcome::RolledBack { .. } => {}
            other => panic!("rollback should win the tie, got {other:?}"),
        }
        assert_eq!(lp.busy.unwrap().event.thread, 7);
        lp.busy = None;
        let _ = lp.start_next(0, cost, 0);
        assert_eq!(lp.busy.unwrap().event.thread, 3, "lower thread id wins");
    }

    #[test]
    fn delayed_events_not_ready() {
        let mut lp = Lp::default();
        let mut e = Event::injection(1, 5, 1);
        e.tick = 2;
        lp.receive(e, 0); // ready at wall tick 2
        assert!(matches!(lp.start_next(0, cost, 0), StartOutcome::Nothing));
        assert!(matches!(lp.start_next(1, cost, 0), StartOutcome::Nothing));
        assert!(matches!(lp.start_next(2, cost, 0), StartOutcome::Started { .. }));
    }

    #[test]
    fn earliest_event_at_tracks_delays() {
        let mut lp = Lp::default();
        assert_eq!(lp.earliest_event_at(0), None);
        let mut e = Event::injection(1, 5, 1);
        e.tick = 4;
        lp.receive(e, 10); // ready at 14
        assert_eq!(lp.earliest_event_at(10), Some(14));
        assert_eq!(lp.earliest_event_at(13), Some(14));
        assert_eq!(lp.earliest_event_at(14), Some(14));
        assert_eq!(lp.earliest_event_at(20), Some(20), "ready now");
    }

    #[test]
    fn busy_completes_at_done_at() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(1, 5, 0), 0);
        let _ = lp.start_next(3, cost, 0); // cost 2 => done_at = 4
        assert!(lp.complete_busy(3).is_none());
        let done = lp.complete_busy(4).expect("completes at tick 4");
        assert_eq!(done.thread, 1);
        assert!(lp.busy.is_none());
    }

    #[test]
    fn straggler_triggers_rollback_and_antimessages() {
        let mut lp = Lp::default();
        // Process event at t=20 that forwarded to neighbor 3.
        lp.local_time = 20;
        lp.mark_seen(9);
        lp.retire(
            Event { thread: 9, time: 20, kind: EventKind::ProcessForward, tick: 0, count: 1 },
            &[3],
        );
        // Straggler at t=10 arrives.
        lp.receive(Event::injection(4, 10, 0), 0);
        match lp.start_next(0, cost, 1) {
            StartOutcome::Started { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 1);
                assert_eq!(cancellations.len(), 1);
                assert_eq!(cancellations[0].0, 3);
                assert_eq!(cancellations[0].1.kind, EventKind::Rollback);
                assert_eq!(cancellations[0].1.thread, 9);
            }
            other => panic!("expected Started, got {other:?}"),
        }
        // The rolled-back event is pending again; local time fell back.
        assert!(pending_of(&lp).iter().any(|e| e.thread == 9));
        assert_eq!(lp.local_time, 10);
        assert_eq!(lp.rollbacks, 1);
    }

    #[test]
    fn rollback_event_on_processed_thread_cascades() {
        let mut lp = Lp::default();
        lp.local_time = 30;
        lp.mark_seen(1);
        lp.mark_seen(2);
        lp.retire(
            Event { thread: 1, time: 10, kind: EventKind::ProcessForward, tick: 0, count: 1 },
            &[7],
        );
        lp.retire(
            Event { thread: 2, time: 20, kind: EventKind::ProcessOnly, tick: 0, count: 0 },
            &[],
        );
        // Anti-message for thread 1 (t=10): must undo thread 2 as well.
        lp.receive(
            Event { thread: 1, time: 10, kind: EventKind::Rollback, tick: 0, count: 0 },
            0,
        );
        match lp.start_next(0, cost, 0) {
            StartOutcome::RolledBack { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 2);
                // Thread 1's forward to 7 must be chased.
                assert!(cancellations.iter().any(|(n, e)| *n == 7 && e.thread == 1));
            }
            other => panic!("expected RolledBack, got {other:?}"),
        }
        // Thread 1 is gone (unseen), thread 2 restored to pending.
        assert!(!lp.has_seen(1));
        assert!(pending_of(&lp).iter().any(|e| e.thread == 2));
        assert!(!pending_of(&lp)
            .iter()
            .any(|e| e.thread == 1 && e.kind != EventKind::Rollback));
    }

    #[test]
    fn fossil_collection_drops_old_history() {
        let mut lp = Lp::default();
        for t in [5u64, 10, 15] {
            lp.retire(
                Event { thread: t, time: t, kind: EventKind::ProcessOnly, tick: 0, count: 0 },
                &[],
            );
        }
        lp.fossil_collect(10);
        assert_eq!(lp.history_len(), 2);
        assert!(lp.history_entries().all(|(e, _)| e.time >= 10));
    }

    #[test]
    fn late_antimessage_is_harmless() {
        let mut lp = Lp::default();
        lp.receive(
            Event { thread: 42, time: 5, kind: EventKind::Rollback, tick: 0, count: 0 },
            0,
        );
        match lp.start_next(0, cost, 0) {
            StartOutcome::RolledBack { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 0);
                assert!(cancellations.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn min_pending_time_and_drain() {
        let mut lp = Lp::default();
        assert!(lp.idle_and_empty());
        assert_eq!(lp.min_pending_time(), None);
        lp.receive(Event::injection(1, 9, 0), 0);
        lp.receive(Event::injection(2, 4, 0), 0);
        assert_eq!(lp.min_pending_time(), Some(4));
        assert!(!lp.idle_and_empty());
        // Removing the current minimum recomputes the cache.
        let _ = lp.start_next(0, cost, 0); // starts thread 2 (t=4)
        assert_eq!(lp.min_pending_time(), Some(9));
        assert_eq!(lp.gvt_contribution(), Some(4), "busy event holds GVT");
    }

    #[test]
    fn slot_reuse_does_not_resurrect_stale_heap_entries() {
        let mut lp = Lp::default();
        // Fill and annihilate to cycle slots through the free list.
        for round in 0..5u64 {
            let e = Event::injection(100 + round, 50 - round, 0);
            lp.receive(e, 0);
            lp.receive(e.rollback_for(0), 0);
        }
        assert_eq!(lp.queue_len(), 0);
        // Now a real event: stale ready-heap entries must not shadow it.
        lp.receive(Event::injection(7, 99, 0), 0);
        match lp.start_next(0, cost, 0) {
            StartOutcome::Started { .. } => {}
            other => panic!("expected start, got {other:?}"),
        }
        assert_eq!(lp.busy.unwrap().event.thread, 7);
    }

    #[test]
    fn restore_pending_round_trips_events_and_readiness() {
        let mut lp = Lp::default();
        let mut delayed = Event::injection(4, 40, 1);
        delayed.tick = 9; // ready at 14
        lp.receive(Event::injection(1, 30, 1), 5);
        lp.receive(Event::injection(2, 10, 1), 5);
        lp.receive(delayed, 5);
        lp.mark_seen(99); // processed-history marker, restored separately

        let mut items: Vec<(Event, WallTime)> = lp.pending_with_ready_at().collect();
        items.sort_by_key(|(e, r)| (e.time, e.kind.rank(), e.thread, e.count, *r));
        let mut restored = Lp::default();
        restored.restore_pending(items.clone(), 5);
        for t in lp.seen_threads() {
            restored.mark_seen(t);
        }
        restored.local_time = lp.local_time;

        assert_eq!(restored.queue_len(), lp.queue_len());
        assert_eq!(restored.earliest_event_at(5), lp.earliest_event_at(5));
        assert_eq!(restored.min_pending_time(), lp.min_pending_time());
        // Both replicas drain in the same order.
        for now in [5u64, 14] {
            let a = match lp.start_next(now, cost, 0) {
                StartOutcome::Started { .. } => lp.busy.unwrap().event,
                other => panic!("{other:?}"),
            };
            let b = match restored.start_next(now, cost, 0) {
                StartOutcome::Started { .. } => restored.busy.unwrap().event,
                other => panic!("{other:?}"),
            };
            assert_eq!(a.thread, b.thread);
            assert_eq!(a.time, b.time);
            lp.busy = None;
            restored.busy = None;
        }
        // A second capture from the restored LP yields the same multiset.
        let mut again: Vec<(Event, WallTime)> = restored.pending_with_ready_at().collect();
        again.sort_by_key(|(e, r)| (e.time, e.kind.rank(), e.thread, e.count, *r));
        let mut orig: Vec<(Event, WallTime)> = lp.pending_with_ready_at().collect();
        orig.sort_by_key(|(e, r)| (e.time, e.kind.rank(), e.thread, e.count, *r));
        assert_eq!(again.len(), orig.len());
        for ((ea, ra), (eb, rb)) in again.iter().zip(orig.iter()) {
            assert_eq!((ea.thread, ea.time, ea.kind, ea.count, ra), (eb.thread, eb.time, eb.kind, eb.count, rb));
        }
    }

    #[test]
    fn queue_len_counts_live_events() {
        let mut lp = Lp::default();
        for t in 0..10u64 {
            lp.receive(Event::injection(t + 1, t, 0), 0);
        }
        assert_eq!(lp.queue_len(), 10);
        let _ = lp.start_next(0, cost, 0);
        assert_eq!(lp.queue_len(), 9);
        assert_eq!(lp.pending_events().count(), 9);
    }

    #[test]
    fn seen_threads_iterates_ascending_across_words() {
        let mut lp = Lp::default();
        for t in [200u64, 3, 64, 65, 0] {
            lp.mark_seen(t);
        }
        let seen: Vec<ThreadId> = lp.seen_threads().collect();
        assert_eq!(seen, vec![0, 3, 64, 65, 200]);
        lp.unmark_seen(64);
        let seen: Vec<ThreadId> = lp.seen_threads().collect();
        assert_eq!(seen, vec![0, 3, 65, 200]);
    }

    #[test]
    fn reserve_threads_presizes_and_is_idempotent() {
        let mut lp = Lp::default();
        lp.reserve_threads(130);
        let slots_cap = lp.thread_slot.len();
        let words = lp.seen_words.len();
        assert!(slots_cap >= 130);
        assert_eq!(words, 3, "130 threads span 3 bitset words");
        // Receiving threads below the bound must not grow anything.
        lp.receive(Event::injection(129, 5, 1), 0);
        lp.receive(Event::injection(0, 6, 1), 0);
        assert_eq!(lp.thread_slot.len(), slots_cap);
        assert_eq!(lp.seen_words.len(), words);
        lp.reserve_threads(64); // shrinking request is a no-op
        assert_eq!(lp.thread_slot.len(), slots_cap);
    }

    #[test]
    fn arena_compacts_in_place_preserving_history() {
        let mut lp = Lp::default();
        // Retire 40 events with 4 forwards each: arena = 160 entries.
        for t in 0..40u64 {
            let fwd = [1usize, 2, 3, 4];
            lp.retire(
                Event { thread: t + 1, time: t, kind: EventKind::ProcessForward, tick: 0, count: 1 },
                &fwd,
            );
        }
        assert_eq!(lp.fwd_arena.len(), 160);
        // Collect the first 30: 120 arena entries die; compaction kicks
        // in (160 > 2 * 40) and slides the 10 live spans down.
        lp.fossil_collect(30);
        assert_eq!(lp.history_len(), 10);
        assert_eq!(lp.fwd_arena.len(), 40, "arena compacted to live spans");
        assert_eq!(lp.arena_live, 40);
        for (e, fwd) in lp.history_entries() {
            assert!(e.time >= 30);
            assert_eq!(fwd, &[1usize, 2, 3, 4]);
        }
    }

    #[test]
    fn history_round_trips_through_restore() {
        let mut lp = Lp::default();
        lp.retire(
            Event { thread: 1, time: 10, kind: EventKind::ProcessForward, tick: 0, count: 2 },
            &[5, 6],
        );
        lp.retire(
            Event { thread: 2, time: 12, kind: EventKind::ProcessOnly, tick: 0, count: 0 },
            &[],
        );
        let entries: Vec<(Event, Vec<NodeId>)> =
            lp.history_entries().map(|(e, f)| (e, f.to_vec())).collect();
        let mut restored = Lp::default();
        restored.restore_history(entries.clone());
        let back: Vec<(Event, Vec<NodeId>)> =
            restored.history_entries().map(|(e, f)| (e, f.to_vec())).collect();
        assert_eq!(back.len(), entries.len());
        for ((ea, fa), (eb, fb)) in back.iter().zip(entries.iter()) {
            assert_eq!((ea.thread, ea.time, ea.count), (eb.thread, eb.time, eb.count));
            assert_eq!(fa, fb);
        }
    }
}

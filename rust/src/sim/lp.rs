//! Logical-process state (paper Table II) and per-LP operations.
//!
//! Each LP carries its pending event set, the history of processed
//! events (needed for rollback), its local virtual time, and its busy
//! state. The LP-level operations implemented here are the bodies of the
//! paper's Fig. 4 (`Process_noncausal_event`) and Fig. 5
//! (`Process_rollback_event`), restructured as pure state transitions
//! that *return* the anti-messages to send so the engine owns all
//! message routing.
//!
//! # Indexed pending structure
//!
//! The original implementation kept `pending` as a flat `Vec<Event>` and
//! linearly scanned it for the next ready event, the minimum pending
//! timestamp (GVT contribution) and annihilation twins — O(queue) per
//! tick per LP. This version indexes the pending set so every hot-path
//! query is O(log queue) amortized or O(1):
//!
//! * events live in a **slot slab** (`slots` + free list + per-slot
//!   generation counters), so heap entries can reference them stably;
//! * a **ready-min heap** keyed `(time, kind-rank, thread)` yields the
//!   next event to execute (rollbacks win ties so cancellations happen
//!   promptly; the thread id makes selection a total order, independent
//!   of arrival order — required for the deterministic parallel tick);
//! * a **delayed heap** keyed by absolute ready wall-tick replaces the
//!   per-tick transfer-delay countdown: an event received at wall tick
//!   `now` with transfer delay `d` becomes ready at `now + d`, and is
//!   promoted into the ready heap lazily. No per-tick work at all for
//!   in-flight events — which is also what makes the engine's tick
//!   fast-forward O(1) per skipped tick;
//! * a **per-thread slot map** finds a pending non-rollback twin for
//!   anti-message annihilation in O(1) (an LP holds at most one live
//!   non-rollback event per thread — the flood-forwarding filter
//!   guarantees it);
//! * the minimum pending timestamp (the LP's GVT contribution) comes
//!   from a third lazy min-heap keyed by event time — amortized
//!   O(log queue) even when the minimum itself is removed.
//!
//! Heap entries are invalidated lazily: removing an event bumps its
//! slot's generation, and stale heap entries are discarded on pop.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};

use crate::graph::NodeId;
use crate::sim::event::{Event, EventKind, SimTime, ThreadId, WallTime};

/// A processed event retained for possible rollback, together with the
/// forwards it generated (so anti-messages can chase them).
#[derive(Debug, Clone)]
pub struct HistoryEntry {
    pub event: Event,
    /// Neighbors this event's processing forwarded the thread to.
    pub forwarded_to: Vec<NodeId>,
}

/// Busy state: the event being processed and the wall tick during whose
/// phase-completion pass it finishes (absolute, not a countdown).
#[derive(Debug, Clone, Copy)]
pub struct Busy {
    pub event: Event,
    /// Completion wall tick: a cost-`c` event started during tick `t`
    /// completes during tick `t + c - 1` (a cost-1 event completes the
    /// same tick it starts, as in the countdown formulation).
    pub done_at: WallTime,
}

/// Outcome of selecting and starting the next event on an LP.
#[derive(Debug)]
pub enum StartOutcome {
    /// Nothing ready (empty list or all events still delayed).
    Nothing,
    /// Started processing a (causal or straggler) event; anti-messages
    /// in `.cancellations` must be delivered by the engine.
    Started { rolled_back: usize, cancellations: Vec<(NodeId, Event)> },
    /// Consumed a rollback anti-message; may itself cascade.
    RolledBack { rolled_back: usize, cancellations: Vec<(NodeId, Event)> },
}

/// Ordering rank of an event kind in the ready queue: rollbacks first.
#[inline]
fn kind_rank(kind: EventKind) -> u8 {
    match kind {
        EventKind::Rollback => 0,
        _ => 1,
    }
}

type SlotIdx = u32;

/// One slab slot. `gen` increments every time the slot is vacated, so
/// stale heap entries (which carry the generation they were pushed
/// under) can be recognized and discarded.
#[derive(Debug, Clone, Default)]
struct Slot {
    gen: u32,
    ev: Option<Event>,
    /// Absolute wall tick at which the event becomes processable.
    ready_at: WallTime,
}

/// Ready-heap key: total order `(time, kind-rank, thread)`; the slot
/// index only breaks ties between byte-identical duplicate events.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct ReadyKey {
    time: SimTime,
    rank: u8,
    thread: ThreadId,
    slot: SlotIdx,
    gen: u32,
}

/// Delayed-heap key: absolute readiness tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct DelayKey {
    ready_at: WallTime,
    slot: SlotIdx,
    gen: u32,
}

/// Time-heap key: the event timestamp (GVT contribution index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct TimeKey {
    time: SimTime,
    slot: SlotIdx,
    gen: u32,
}

/// One logical process (Table II).
#[derive(Debug, Clone)]
pub struct Lp {
    /// Slot slab holding the pending events.
    slots: Vec<Slot>,
    /// Vacant slot indices.
    free: Vec<SlotIdx>,
    /// Number of live pending events.
    live: usize,
    /// Ready events, min-first by `(time, kind-rank, thread)`. Lazy.
    ready: BinaryHeap<Reverse<ReadyKey>>,
    /// Not-yet-ready events, min-first by absolute ready tick. Lazy.
    delayed: BinaryHeap<Reverse<DelayKey>>,
    /// All live events, min-first by timestamp — the LP's GVT
    /// contribution. Lazy (stale entries popped on query), so removing
    /// the current minimum costs O(log q), not a slab rescan.
    times: BinaryHeap<Reverse<TimeKey>>,
    /// Pending non-rollback event slot per thread (annihilation index).
    thread_slot: HashMap<ThreadId, SlotIdx>,
    /// Threads present in `pending` or `history` — the "has it received
    /// this packet yet" test used by the flood-forwarding rule.
    pub seen: HashSet<ThreadId>,
    /// Local virtual time (timestamp of last/current processed event).
    pub local_time: SimTime,
    /// Busy processing state (`status?`, absolute completion tick).
    pub busy: Option<Busy>,
    /// Processed-event history (`*-history` columns).
    pub history: Vec<HistoryEntry>,
    /// Rollback counter (statistics).
    pub rollbacks: u64,
}

impl Default for Lp {
    fn default() -> Self {
        Lp {
            slots: Vec::new(),
            free: Vec::new(),
            live: 0,
            ready: BinaryHeap::new(),
            delayed: BinaryHeap::new(),
            times: BinaryHeap::new(),
            thread_slot: HashMap::new(),
            seen: HashSet::new(),
            local_time: 0,
            busy: None,
            history: Vec::new(),
            rollbacks: 0,
        }
    }
}

impl Lp {
    /// Insert an event into the slab and the appropriate heap. The
    /// event's relative `tick` delay is converted to an absolute ready
    /// tick against `now` and then cleared.
    fn insert_event(&mut self, ev: Event, now: WallTime) {
        let ready_at = now + ev.tick;
        self.insert_event_at(ev, ready_at, now);
    }

    /// Insert an event with an explicit absolute ready tick (snapshot
    /// restore path: `ready_at` may be in the past when the LP was busy
    /// while the event sat ready). The event's relative `tick` must
    /// already be folded into `ready_at`; it is cleared on insertion.
    fn insert_event_at(&mut self, ev: Event, ready_at: WallTime, now: WallTime) {
        let ev = Event { tick: 0, ..ev };
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(Slot::default());
                (self.slots.len() - 1) as SlotIdx
            }
        };
        let gen = {
            let s = &mut self.slots[slot as usize];
            debug_assert!(s.ev.is_none(), "allocated an occupied slot");
            s.ev = Some(ev);
            s.ready_at = ready_at;
            s.gen
        };
        if ev.kind != EventKind::Rollback {
            // At most one live non-rollback event per thread is the
            // steady-state invariant (the flood filter guarantees it for
            // forwards); duplicate *injections* of one thread id are
            // tolerated by keeping the first mapping, so an anti-message
            // annihilates the older twin — matching the linear-scan
            // reference stepper.
            self.thread_slot.entry(ev.thread).or_insert(slot);
        }
        if ready_at <= now {
            self.ready.push(Reverse(ReadyKey {
                time: ev.time,
                rank: kind_rank(ev.kind),
                thread: ev.thread,
                slot,
                gen,
            }));
        } else {
            self.delayed.push(Reverse(DelayKey { ready_at, slot, gen }));
        }
        self.times.push(Reverse(TimeKey { time: ev.time, slot, gen }));
        self.live += 1;
    }

    /// Vacate a slot, maintaining the thread map and the cached time
    /// minimum. Stale heap entries are left behind (generation bump
    /// invalidates them).
    fn remove_slot(&mut self, slot: SlotIdx) -> Event {
        let s = &mut self.slots[slot as usize];
        let ev = s.ev.take().expect("removing an empty slot");
        s.gen = s.gen.wrapping_add(1);
        self.free.push(slot);
        self.live -= 1;
        if ev.kind != EventKind::Rollback {
            if let Some(&mapped) = self.thread_slot.get(&ev.thread) {
                if mapped == slot {
                    self.thread_slot.remove(&ev.thread);
                }
            }
        }
        ev
    }

    /// True if the heap entry still refers to the event it was pushed
    /// for.
    #[inline]
    fn slot_live(&self, slot: SlotIdx, gen: u32) -> bool {
        let s = &self.slots[slot as usize];
        s.gen == gen && s.ev.is_some()
    }

    /// Move events whose ready tick has arrived into the ready heap.
    fn promote(&mut self, now: WallTime) {
        while let Some(&Reverse(key)) = self.delayed.peek() {
            if key.ready_at > now {
                break;
            }
            self.delayed.pop();
            if !self.slot_live(key.slot, key.gen) {
                continue;
            }
            let s = &self.slots[key.slot as usize];
            debug_assert_eq!(s.ready_at, key.ready_at);
            let ev = s.ev.expect("live slot has an event");
            self.ready.push(Reverse(ReadyKey {
                time: ev.time,
                rank: kind_rank(ev.kind),
                thread: ev.thread,
                slot: key.slot,
                gen: key.gen,
            }));
        }
    }

    /// Slot of the ready pending event with the lowest
    /// `(time, kind-rank, thread)` key, discarding stale heap entries.
    fn peek_ready(&mut self, now: WallTime) -> Option<SlotIdx> {
        self.promote(now);
        while let Some(&Reverse(key)) = self.ready.peek() {
            if self.slot_live(key.slot, key.gen) {
                return Some(key.slot);
            }
            self.ready.pop();
        }
        None
    }

    /// Earliest wall tick at which this LP has (or will have) a
    /// processable event, given it stays unperturbed: `Some(now)` if an
    /// event is ready, the delayed minimum otherwise. Drives the
    /// engine's tick fast-forward.
    pub fn earliest_event_at(&mut self, now: WallTime) -> Option<WallTime> {
        if self.peek_ready(now).is_some() {
            return Some(now);
        }
        while let Some(&Reverse(key)) = self.delayed.peek() {
            if self.slot_live(key.slot, key.gen) {
                return Some(key.ready_at);
            }
            self.delayed.pop();
        }
        None
    }

    /// Enqueue an arriving event at wall tick `now`. Rollback
    /// anti-messages may annihilate a pending event immediately
    /// (standard Time Warp optimization); everything else joins the
    /// pending set, becoming ready `ev.tick` ticks from now.
    pub fn receive(&mut self, ev: Event, now: WallTime) {
        if ev.kind == EventKind::Rollback {
            // Annihilate the in-flight (pending) twin if present.
            if let Some(&slot) = self.thread_slot.get(&ev.thread) {
                self.remove_slot(slot);
                self.seen.remove(&ev.thread);
                return;
            }
        } else {
            self.seen.insert(ev.thread);
        }
        self.insert_event(ev, now);
    }

    /// Has this LP seen the thread (pending or processed)? This is the
    /// flood-forwarding filter of Fig. 6.
    pub fn has_seen(&self, thread: ThreadId) -> bool {
        self.seen.contains(&thread)
    }

    /// Roll local state back so that all history entries with
    /// `event.time > horizon` return to the pending set; returns the
    /// anti-messages for the forwards those entries had generated.
    /// (Body of Fig. 4's restoration loop.)
    fn rollback_to(
        &mut self,
        horizon: SimTime,
        transfer_delay: WallTime,
        now: WallTime,
    ) -> (usize, Vec<(NodeId, Event)>) {
        let mut cancellations = Vec::new();
        let mut restored = 0;
        let mut kept = Vec::with_capacity(self.history.len());
        for entry in std::mem::take(&mut self.history) {
            if entry.event.time > horizon {
                restored += 1;
                for &nb in &entry.forwarded_to {
                    // Anti-messages match on thread id at the receiver, so
                    // the parent event's own (thread, time) is sufficient.
                    cancellations.push((nb, entry.event.rollback_for(transfer_delay)));
                }
                // The event returns to the pending set to be re-executed
                // immediately (no transfer delay: it is already local).
                self.insert_event(Event { tick: 0, ..entry.event }, now);
            } else {
                kept.push(entry);
            }
        }
        self.history = kept;
        // Local time falls back to the horizon.
        self.local_time = self.local_time.min(horizon);
        if restored > 0 {
            self.rollbacks += 1;
        }
        (restored, cancellations)
    }

    /// Consume a rollback anti-message aimed at `thread` (Fig. 5): if the
    /// thread was already processed, roll back past it and drop it; the
    /// annihilation-in-pending case is handled in [`Self::receive`].
    fn process_rollback(
        &mut self,
        ev: Event,
        transfer_delay: WallTime,
        now: WallTime,
    ) -> (usize, Vec<(NodeId, Event)>) {
        // Find the processed instance of this thread.
        if let Some(pos) = self.history.iter().position(|h| h.event.thread == ev.thread) {
            let target_time = self.history[pos].event.time;
            // Undo everything after (and including) the cancelled event.
            let (restored, cancellations) =
                self.rollback_to(target_time.saturating_sub(1), transfer_delay, now);
            // The cancelled thread itself must not be re-executed: drop it
            // from pending (rollback_to restored it) and un-see it.
            if let Some(&slot) = self.thread_slot.get(&ev.thread) {
                self.remove_slot(slot);
            }
            self.seen.remove(&ev.thread);
            // Cancellations for the dropped event's own forwards were
            // already produced by rollback_to (it was in the restored set).
            return (restored, cancellations);
        }
        // Late anti-message for a thread we never processed (its twin was
        // annihilated in pending, or never arrived): nothing to do.
        (0, Vec::new())
    }

    /// Select the next ready event and start processing it — the Fig. 6
    /// idle-branch, at wall tick `now`. `occupancy_cost` is the busy
    /// time charged for the event (already scaled by machine occupancy
    /// by the engine).
    pub fn start_next(
        &mut self,
        now: WallTime,
        occupancy_cost: impl Fn(EventKind) -> WallTime,
        transfer_delay: WallTime,
    ) -> StartOutcome {
        debug_assert!(self.busy.is_none());
        let Some(slot) = self.peek_ready(now) else {
            return StartOutcome::Nothing;
        };
        let ev = self.remove_slot(slot);
        match ev.kind {
            EventKind::Rollback => {
                let (rolled_back, cancellations) = self.process_rollback(ev, transfer_delay, now);
                // Rollback handling occupies the LP (synchronization
                // overhead): busy for its base cost.
                let cost = occupancy_cost(EventKind::Rollback).max(1);
                self.busy = Some(Busy { event: ev, done_at: now + cost - 1 });
                StartOutcome::RolledBack { rolled_back, cancellations }
            }
            _ => {
                let mut rolled_back = 0;
                let mut cancellations = Vec::new();
                if ev.time < self.local_time {
                    // Straggler — Fig. 4 Process_noncausal_event.
                    let (r, c) = self.rollback_to(ev.time, transfer_delay, now);
                    rolled_back = r;
                    cancellations = c;
                }
                self.local_time = self.local_time.max(ev.time);
                let cost = occupancy_cost(ev.kind).max(1);
                self.busy = Some(Busy { event: ev, done_at: now + cost - 1 });
                StartOutcome::Started { rolled_back, cancellations }
            }
        }
    }

    /// Completion check for wall tick `now`: returns the processed event
    /// when the busy period ends this tick (replaces the per-tick
    /// countdown of the naive formulation).
    pub fn complete_busy(&mut self, now: WallTime) -> Option<Event> {
        match self.busy {
            Some(b) if b.done_at <= now => {
                self.busy = None;
                Some(b.event)
            }
            _ => None,
        }
    }

    /// Record a completed non-rollback event into history together with
    /// the forwards it generated.
    pub fn retire(&mut self, event: Event, forwarded_to: Vec<NodeId>) {
        debug_assert_ne!(event.kind, EventKind::Rollback);
        self.history.push(HistoryEntry { event, forwarded_to });
    }

    /// Fossil collection (App. B): drop history entries strictly older
    /// than the global virtual time — no rollback can ever reach them.
    /// Engines may defer this on idle LPs and catch up on reactivation.
    pub fn fossil_collect(&mut self, gvt: SimTime) {
        self.history.retain(|h| h.event.time >= gvt);
    }

    /// Lowest timestamp among pending events (regardless of delay), used
    /// in the GVT computation. Amortized O(log q) (lazy stale pops).
    pub fn min_pending_time(&mut self) -> Option<SimTime> {
        while let Some(&Reverse(key)) = self.times.peek() {
            if self.slot_live(key.slot, key.gen) {
                return Some(key.time);
            }
            self.times.pop();
        }
        None
    }

    /// This LP's GVT contribution: the minimum of its busy event's
    /// timestamp and its minimum pending timestamp.
    pub fn gvt_contribution(&mut self) -> Option<SimTime> {
        let busy = self.busy.as_ref().map(|b| b.event.time);
        match (busy, self.min_pending_time()) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, Some(b)) => Some(b),
            (None, None) => None,
        }
    }

    /// Is the LP completely drained?
    pub fn idle_and_empty(&self) -> bool {
        self.busy.is_none() && self.live == 0
    }

    /// Current queue length (the paper's dynamic node weight b_i, §6.1).
    pub fn queue_len(&self) -> usize {
        self.live
    }

    /// Iterate the live pending events (arbitrary order).
    pub fn pending_events(&self) -> impl Iterator<Item = &Event> {
        self.slots.iter().filter_map(|s| s.ev.as_ref())
    }

    /// Iterate the live pending events together with their absolute
    /// ready wall tick (arbitrary order). Snapshot capture sorts these
    /// into the canonical `(time, kind-rank, thread, count, ready_at)`
    /// order before serializing, so the index layout (slots, heap entry
    /// order, generations) never leaks into the snapshot bytes.
    pub fn pending_with_ready_at(&self) -> impl Iterator<Item = (Event, WallTime)> + '_ {
        self.slots.iter().filter_map(|s| s.ev.map(|ev| (ev, s.ready_at)))
    }

    /// Rebuild the pending set from `(event, absolute ready tick)` pairs
    /// at wall tick `now` (snapshot restore). The LP must be freshly
    /// constructed: the slab is rebuilt from scratch so heap keys and
    /// the per-thread annihilation map are re-derived deterministically
    /// from the insertion order (callers pass the canonical sorted
    /// order).
    pub fn restore_pending(
        &mut self,
        events: impl IntoIterator<Item = (Event, WallTime)>,
        now: WallTime,
    ) {
        assert!(self.live == 0 && self.slots.is_empty(), "restore into a non-empty pending set");
        for (ev, ready_at) in events {
            self.insert_event_at(ev, ready_at, now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(_k: EventKind) -> WallTime {
        2
    }

    /// Collect pending events sorted for comparisons.
    fn pending_of(lp: &Lp) -> Vec<Event> {
        let mut v: Vec<Event> = lp.pending_events().copied().collect();
        v.sort_by_key(|e| (e.time, kind_rank(e.kind), e.thread));
        v
    }

    #[test]
    fn receive_tracks_seen() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(5, 10, 2), 0);
        assert!(lp.has_seen(5));
        assert!(!lp.has_seen(6));
        assert_eq!(lp.queue_len(), 1);
    }

    #[test]
    fn rollback_annihilates_pending_twin() {
        let mut lp = Lp::default();
        let e = Event::injection(5, 10, 2);
        lp.receive(e, 0);
        lp.receive(e.rollback_for(0), 0);
        assert_eq!(lp.queue_len(), 0, "twin should annihilate");
        assert!(!lp.has_seen(5));
        assert!(lp.idle_and_empty());
    }

    #[test]
    fn annihilation_finds_delayed_twin() {
        let mut lp = Lp::default();
        let mut e = Event::injection(5, 10, 2);
        e.tick = 7; // still in flight
        lp.receive(e, 3);
        assert_eq!(lp.queue_len(), 1);
        lp.receive(e.rollback_for(0), 4);
        assert_eq!(lp.queue_len(), 0);
        assert!(!lp.has_seen(5));
    }

    #[test]
    fn starts_lowest_timestamp_first() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(1, 30, 1), 0);
        lp.receive(Event::injection(2, 10, 1), 0);
        match lp.start_next(0, cost, 0) {
            StartOutcome::Started { .. } => {}
            other => panic!("expected start, got {other:?}"),
        }
        assert_eq!(lp.busy.unwrap().event.thread, 2);
        assert_eq!(lp.local_time, 10);
    }

    #[test]
    fn equal_time_ties_break_on_kind_then_thread() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(9, 10, 1), 0);
        lp.receive(Event::injection(3, 10, 1), 0);
        // Anti-message for an unrelated thread at the same timestamp.
        lp.receive(
            Event { thread: 7, time: 10, kind: EventKind::Rollback, tick: 0, count: 0 },
            0,
        );
        match lp.start_next(0, cost, 0) {
            StartOutcome::RolledBack { .. } => {}
            other => panic!("rollback should win the tie, got {other:?}"),
        }
        assert_eq!(lp.busy.unwrap().event.thread, 7);
        lp.busy = None;
        let _ = lp.start_next(0, cost, 0);
        assert_eq!(lp.busy.unwrap().event.thread, 3, "lower thread id wins");
    }

    #[test]
    fn delayed_events_not_ready() {
        let mut lp = Lp::default();
        let mut e = Event::injection(1, 5, 1);
        e.tick = 2;
        lp.receive(e, 0); // ready at wall tick 2
        assert!(matches!(lp.start_next(0, cost, 0), StartOutcome::Nothing));
        assert!(matches!(lp.start_next(1, cost, 0), StartOutcome::Nothing));
        assert!(matches!(lp.start_next(2, cost, 0), StartOutcome::Started { .. }));
    }

    #[test]
    fn earliest_event_at_tracks_delays() {
        let mut lp = Lp::default();
        assert_eq!(lp.earliest_event_at(0), None);
        let mut e = Event::injection(1, 5, 1);
        e.tick = 4;
        lp.receive(e, 10); // ready at 14
        assert_eq!(lp.earliest_event_at(10), Some(14));
        assert_eq!(lp.earliest_event_at(13), Some(14));
        assert_eq!(lp.earliest_event_at(14), Some(14));
        assert_eq!(lp.earliest_event_at(20), Some(20), "ready now");
    }

    #[test]
    fn busy_completes_at_done_at() {
        let mut lp = Lp::default();
        lp.receive(Event::injection(1, 5, 0), 0);
        let _ = lp.start_next(3, cost, 0); // cost 2 => done_at = 4
        assert!(lp.complete_busy(3).is_none());
        let done = lp.complete_busy(4).expect("completes at tick 4");
        assert_eq!(done.thread, 1);
        assert!(lp.busy.is_none());
    }

    #[test]
    fn straggler_triggers_rollback_and_antimessages() {
        let mut lp = Lp::default();
        // Process event at t=20 that forwarded to neighbor 3.
        lp.local_time = 20;
        lp.seen.insert(9);
        lp.retire(
            Event { thread: 9, time: 20, kind: EventKind::ProcessForward, tick: 0, count: 1 },
            vec![3],
        );
        // Straggler at t=10 arrives.
        lp.receive(Event::injection(4, 10, 0), 0);
        match lp.start_next(0, cost, 1) {
            StartOutcome::Started { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 1);
                assert_eq!(cancellations.len(), 1);
                assert_eq!(cancellations[0].0, 3);
                assert_eq!(cancellations[0].1.kind, EventKind::Rollback);
                assert_eq!(cancellations[0].1.thread, 9);
            }
            other => panic!("expected Started, got {other:?}"),
        }
        // The rolled-back event is pending again; local time fell back.
        assert!(pending_of(&lp).iter().any(|e| e.thread == 9));
        assert_eq!(lp.local_time, 10);
        assert_eq!(lp.rollbacks, 1);
    }

    #[test]
    fn rollback_event_on_processed_thread_cascades() {
        let mut lp = Lp::default();
        lp.local_time = 30;
        lp.seen.insert(1);
        lp.seen.insert(2);
        lp.retire(
            Event { thread: 1, time: 10, kind: EventKind::ProcessForward, tick: 0, count: 1 },
            vec![7],
        );
        lp.retire(
            Event { thread: 2, time: 20, kind: EventKind::ProcessOnly, tick: 0, count: 0 },
            vec![],
        );
        // Anti-message for thread 1 (t=10): must undo thread 2 as well.
        lp.receive(
            Event { thread: 1, time: 10, kind: EventKind::Rollback, tick: 0, count: 0 },
            0,
        );
        match lp.start_next(0, cost, 0) {
            StartOutcome::RolledBack { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 2);
                // Thread 1's forward to 7 must be chased.
                assert!(cancellations.iter().any(|(n, e)| *n == 7 && e.thread == 1));
            }
            other => panic!("expected RolledBack, got {other:?}"),
        }
        // Thread 1 is gone (unseen), thread 2 restored to pending.
        assert!(!lp.has_seen(1));
        assert!(pending_of(&lp).iter().any(|e| e.thread == 2));
        assert!(!pending_of(&lp)
            .iter()
            .any(|e| e.thread == 1 && e.kind != EventKind::Rollback));
    }

    #[test]
    fn fossil_collection_drops_old_history() {
        let mut lp = Lp::default();
        for t in [5u64, 10, 15] {
            lp.retire(
                Event { thread: t, time: t, kind: EventKind::ProcessOnly, tick: 0, count: 0 },
                vec![],
            );
        }
        lp.fossil_collect(10);
        assert_eq!(lp.history.len(), 2);
        assert!(lp.history.iter().all(|h| h.event.time >= 10));
    }

    #[test]
    fn late_antimessage_is_harmless() {
        let mut lp = Lp::default();
        lp.receive(
            Event { thread: 42, time: 5, kind: EventKind::Rollback, tick: 0, count: 0 },
            0,
        );
        match lp.start_next(0, cost, 0) {
            StartOutcome::RolledBack { rolled_back, cancellations } => {
                assert_eq!(rolled_back, 0);
                assert!(cancellations.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn min_pending_time_and_drain() {
        let mut lp = Lp::default();
        assert!(lp.idle_and_empty());
        assert_eq!(lp.min_pending_time(), None);
        lp.receive(Event::injection(1, 9, 0), 0);
        lp.receive(Event::injection(2, 4, 0), 0);
        assert_eq!(lp.min_pending_time(), Some(4));
        assert!(!lp.idle_and_empty());
        // Removing the current minimum recomputes the cache.
        let _ = lp.start_next(0, cost, 0); // starts thread 2 (t=4)
        assert_eq!(lp.min_pending_time(), Some(9));
        assert_eq!(lp.gvt_contribution(), Some(4), "busy event holds GVT");
    }

    #[test]
    fn slot_reuse_does_not_resurrect_stale_heap_entries() {
        let mut lp = Lp::default();
        // Fill and annihilate to cycle slots through the free list.
        for round in 0..5u64 {
            let e = Event::injection(100 + round, 50 - round, 0);
            lp.receive(e, 0);
            lp.receive(e.rollback_for(0), 0);
        }
        assert_eq!(lp.queue_len(), 0);
        // Now a real event: stale ready-heap entries must not shadow it.
        lp.receive(Event::injection(7, 99, 0), 0);
        match lp.start_next(0, cost, 0) {
            StartOutcome::Started { .. } => {}
            other => panic!("expected start, got {other:?}"),
        }
        assert_eq!(lp.busy.unwrap().event.thread, 7);
    }

    #[test]
    fn restore_pending_round_trips_events_and_readiness() {
        let mut lp = Lp::default();
        let mut delayed = Event::injection(4, 40, 1);
        delayed.tick = 9; // ready at 14
        lp.receive(Event::injection(1, 30, 1), 5);
        lp.receive(Event::injection(2, 10, 1), 5);
        lp.receive(delayed, 5);
        lp.seen.insert(99); // processed-history marker, restored separately

        let mut items: Vec<(Event, WallTime)> = lp.pending_with_ready_at().collect();
        items.sort_by_key(|(e, r)| (e.time, kind_rank(e.kind), e.thread, e.count, *r));
        let mut restored = Lp::default();
        restored.restore_pending(items.clone(), 5);
        restored.seen = lp.seen.clone();
        restored.local_time = lp.local_time;

        assert_eq!(restored.queue_len(), lp.queue_len());
        assert_eq!(restored.earliest_event_at(5), lp.earliest_event_at(5));
        assert_eq!(restored.min_pending_time(), lp.min_pending_time());
        // Both replicas drain in the same order.
        for now in [5u64, 14] {
            let a = match lp.start_next(now, cost, 0) {
                StartOutcome::Started { .. } => lp.busy.unwrap().event,
                other => panic!("{other:?}"),
            };
            let b = match restored.start_next(now, cost, 0) {
                StartOutcome::Started { .. } => restored.busy.unwrap().event,
                other => panic!("{other:?}"),
            };
            assert_eq!(a.thread, b.thread);
            assert_eq!(a.time, b.time);
            lp.busy = None;
            restored.busy = None;
        }
        // A second capture from the restored LP yields the same multiset.
        let mut again: Vec<(Event, WallTime)> = restored.pending_with_ready_at().collect();
        again.sort_by_key(|(e, r)| (e.time, kind_rank(e.kind), e.thread, e.count, *r));
        let mut orig: Vec<(Event, WallTime)> = lp.pending_with_ready_at().collect();
        orig.sort_by_key(|(e, r)| (e.time, kind_rank(e.kind), e.thread, e.count, *r));
        assert_eq!(again.len(), orig.len());
        for ((ea, ra), (eb, rb)) in again.iter().zip(orig.iter()) {
            assert_eq!((ea.thread, ea.time, ea.kind, ea.count, ra), (eb.thread, eb.time, eb.kind, eb.count, rb));
        }
    }

    #[test]
    fn queue_len_counts_live_events() {
        let mut lp = Lp::default();
        for t in 0..10u64 {
            lp.receive(Event::injection(t + 1, t, 0), 0);
        }
        assert_eq!(lp.queue_len(), 10);
        let _ = lp.start_next(0, cost, 0);
        assert_eq!(lp.queue_len(), 9);
        assert_eq!(lp.pending_events().count(), 9);
    }
}

//! Software model of an optimistic (Time Warp) parallel discrete-event
//! simulator — the paper's evaluation testbed (§6, Appendix B),
//! re-implemented natively from the NetLogo pseudocode (Figs. 4–6,
//! Tables II–III).
//!
//! The model advances in **wall-clock ticks**. Each LP optimistically
//! processes the lowest-timestamped ready event in its list; processing
//! occupies the LP for `(#LPs resident on its machine) × process-time`
//! ticks (machine speed inversely proportional to resident LPs, §6.1).
//! Cross-machine event transfer pays an `event-tick` wall-clock delay,
//! which is what makes late-arriving stragglers — and thus rollbacks —
//! more likely across a bad partition. The output of a run is the total
//! number of wall-clock ticks to drain all event lists: the paper's
//! *simulation time* metric (Figs. 7–10).

pub mod driver;
pub mod engine;
pub mod event;
pub mod lp;
pub mod weights;
pub mod workload;

pub use engine::{SimEngine, SimOptions, SimStats};
pub use event::{Event, EventKind, ThreadId};
pub use workload::{FloodWorkload, WorkloadOptions};

//! Software model of an optimistic (Time Warp) parallel discrete-event
//! simulator — the paper's evaluation testbed (§6, Appendix B),
//! re-implemented natively from the NetLogo pseudocode (Figs. 4–6,
//! Tables II–III).
//!
//! The model advances in **wall-clock ticks**. Each LP optimistically
//! processes the lowest-timestamped ready event in its list; processing
//! occupies the LP for `(#LPs resident on its machine) × process-time`
//! ticks (machine speed inversely proportional to resident LPs, §6.1).
//! Cross-machine event transfer pays an `event-tick` wall-clock delay,
//! which is what makes late-arriving stragglers — and thus rollbacks —
//! more likely across a bad partition. The output of a run is the total
//! number of wall-clock ticks to drain all event lists: the paper's
//! *simulation time* metric (Figs. 7–10).
//!
//! On top of the engine sit two closed-loop drivers: [`driver`] (the
//! fixed-period loop the Fig. 7–10 harnesses use) and [`dynamic`], the
//! full §6.1 epoch loop with windowed load measurement, pluggable
//! weight estimators, a selectable sequential/distributed refinement
//! backend and per-epoch reporting, fed by the scripted drifting
//! workloads of [`scenario`] — all instances of one serializable
//! schedule genome (`DriftSchedule`). The [`fuzz`] subsystem searches
//! that genome space for adversarial worst-case schedules, shrinks
//! them, and persists them as a replayable corpus
//! (`results/fuzz_corpus/`), cross-checking every evaluation against
//! [`reference`] as a differential oracle.
//!
//! The [`engine`] hot path scales with *activity*, not graph size
//! (active-LP worklist, indexed per-LP event queues, incremental GVT,
//! tick fast-forward, optional parallel per-machine execution — see
//! DESIGN.md §3); [`reference`] retains the naive O(N)-per-tick stepper
//! that the equivalence suite proves it bit-identical to. [`snapshot`]
//! serializes the full engine + game state to versioned, deterministic
//! epoch-boundary checkpoints, which is what lets [`dynamic`] survive
//! worker death by restoring and refining toward the survivors
//! (DESIGN.md §10).

pub mod driver;
pub mod dynamic;
pub mod engine;
pub mod event;
pub mod fuzz;
pub mod legacy;
pub mod lp;
pub mod reference;
pub mod scenario;
pub mod snapshot;
pub mod weights;
pub mod workload;

pub use dynamic::{
    AdmissionRecord, CompareReport, DynamicDriver, DynamicOptions, DynamicReport, EpochReport,
    EstimatorKind, RecoveryRecord, RefineBackend, WeightEstimator,
};
pub use engine::{EpochCounters, SimEngine, SimOptions, SimStats};
pub use event::{Event, EventKind, ThreadId};
pub use fuzz::{FuzzCase, FuzzFixture, FuzzOptions, FuzzOutcome, Objectives};
pub use reference::ReferenceEngine;
pub use scenario::{DriftGene, DriftSchedule, GeneKind, Scenario, ScenarioKind, ScenarioOptions};
pub use snapshot::{EngineState, EstimatorState, LpState, Snapshot, SnapshotError};
pub use workload::{FloodWorkload, WorkloadOptions};

//! Naive reference stepper for the optimistic PDES engine.
//!
//! A deliberately simple, O(N)-per-tick implementation of exactly the
//! semantics `engine::SimEngine` optimizes: flat `Vec<Event>` pending
//! lists with linear scans, per-tick transfer-delay countdowns, a full
//! GVT rescan over every LP and every undelivered injection, per-tick
//! fossil collection on every LP — and no worklist, no fast-forward, no
//! parallelism. It exists so the equivalence suite
//! (`rust/tests/equivalence_engine.rs`) can prove the optimized engine
//! (at every parallelism level) **bit-identical** on `SimStats`,
//! `EpochCounters`, and final GVT. Keep this file boring: its only
//! virtue is being obviously correct.
//!
//! Shared semantics contract (must match `SimEngine` exactly):
//!
//! * event selection is the canonical total order
//!   `(time, kind-rank, thread)` with rollbacks ranked first;
//! * a tick runs start-phase for all LPs (ascending), then
//!   completion/fan-out for all LPs (ascending); `seen` is only mutated
//!   in the start phase, so fan-out reads are order-independent;
//! * messages deliver cancellations first, then forwards, each in
//!   ascending sender order;
//! * an event received with transfer delay `d` during tick `t` becomes
//!   processable in tick `t + d`.

use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};
use crate::sim::engine::{EpochCounters, Injection, SimOptions, SimStats};
use crate::sim::event::{Event, EventKind, SimTime, ThreadId, WallTime};
use crate::util::stats::Trace;
use std::collections::HashSet;

#[derive(Debug, Clone)]
struct RefHistoryEntry {
    event: Event,
    forwarded_to: Vec<NodeId>,
}

/// Flat-scan logical process.
#[derive(Debug, Clone, Default)]
struct RefLp {
    pending: Vec<Event>,
    history: Vec<RefHistoryEntry>,
    seen: HashSet<ThreadId>,
    local_time: SimTime,
    /// `(event, remaining busy ticks)`.
    busy: Option<(Event, WallTime)>,
    rollbacks: u64,
}

/// The shared canonical intra-tick rank ([`EventKind::rank`]): one
/// definition for the optimized engine, the snapshot sort key, and this
/// reference stepper.
#[inline]
fn kind_rank(kind: EventKind) -> u8 {
    kind.rank()
}

impl RefLp {
    fn receive(&mut self, ev: Event) {
        if ev.kind == EventKind::Rollback {
            if let Some(pos) = self
                .pending
                .iter()
                .position(|p| p.thread == ev.thread && p.kind != EventKind::Rollback)
            {
                self.pending.swap_remove(pos);
                self.seen.remove(&ev.thread);
                return;
            }
        } else {
            self.seen.insert(ev.thread);
        }
        self.pending.push(ev);
    }

    fn has_seen(&self, thread: ThreadId) -> bool {
        self.seen.contains(&thread)
    }

    /// Canonical selection: lowest `(time, kind-rank, thread)` among the
    /// ready events.
    fn next_ready(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in self.pending.iter().enumerate() {
            if !e.ready() {
                continue;
            }
            match best {
                Some(b) => {
                    let eb = &self.pending[b];
                    if (e.time, kind_rank(e.kind), e.thread)
                        < (eb.time, kind_rank(eb.kind), eb.thread)
                    {
                        best = Some(i);
                    }
                }
                None => best = Some(i),
            }
        }
        best
    }

    fn rollback_to(
        &mut self,
        horizon: SimTime,
        transfer_delay: WallTime,
    ) -> (usize, Vec<(NodeId, Event)>) {
        let mut cancellations = Vec::new();
        let mut restored = 0;
        let mut kept = Vec::with_capacity(self.history.len());
        for entry in std::mem::take(&mut self.history) {
            if entry.event.time > horizon {
                restored += 1;
                for &nb in &entry.forwarded_to {
                    cancellations.push((nb, entry.event.rollback_for(transfer_delay)));
                }
                self.pending.push(Event { tick: 0, ..entry.event });
            } else {
                kept.push(entry);
            }
        }
        self.history = kept;
        self.local_time = self.local_time.min(horizon);
        if restored > 0 {
            self.rollbacks += 1;
        }
        (restored, cancellations)
    }

    fn process_rollback(
        &mut self,
        ev: Event,
        transfer_delay: WallTime,
    ) -> (usize, Vec<(NodeId, Event)>) {
        if let Some(pos) = self.history.iter().position(|h| h.event.thread == ev.thread) {
            let target_time = self.history[pos].event.time;
            let (restored, cancellations) =
                self.rollback_to(target_time.saturating_sub(1), transfer_delay);
            if let Some(p) = self
                .pending
                .iter()
                .position(|p| p.thread == ev.thread && p.kind != EventKind::Rollback)
            {
                self.pending.swap_remove(p);
            }
            self.seen.remove(&ev.thread);
            return (restored, cancellations);
        }
        (0, Vec::new())
    }

    fn tick_delays(&mut self) {
        for e in &mut self.pending {
            if e.tick > 0 {
                e.tick -= 1;
            }
        }
    }

    fn fossil_collect(&mut self, gvt: SimTime) {
        self.history.retain(|h| h.event.time >= gvt);
    }

    fn min_pending_time(&self) -> Option<SimTime> {
        self.pending.iter().map(|e| e.time).min()
    }

    fn idle_and_empty(&self) -> bool {
        self.busy.is_none() && self.pending.is_empty()
    }
}

/// The naive reference engine. Same constructor shape and observable
/// accessors as [`crate::sim::engine::SimEngine`].
pub struct ReferenceEngine<'g> {
    graph: &'g Graph,
    machines: MachineConfig,
    part: Partition,
    lps: Vec<RefLp>,
    options: SimOptions,
    stats: SimStats,
    gvt: SimTime,
    injections: Vec<Injection>,
    load_traces: Vec<Trace>,
    epoch: EpochCounters,
}

impl<'g> ReferenceEngine<'g> {
    pub fn new(
        graph: &'g Graph,
        machines: MachineConfig,
        part: Partition,
        options: SimOptions,
        mut injections: Vec<Injection>,
    ) -> Self {
        assert_eq!(part.node_count(), graph.node_count());
        assert_eq!(part.machine_count(), machines.count());
        injections.sort_by_key(|inj| std::cmp::Reverse(inj.at_tick));
        let load_traces = (0..machines.count())
            .map(|k| Trace::new(format!("machine{k}")))
            .collect();
        ReferenceEngine {
            graph,
            lps: vec![RefLp::default(); graph.node_count()],
            machines,
            part,
            options,
            stats: SimStats::default(),
            gvt: 0,
            injections,
            load_traces,
            epoch: EpochCounters::for_graph(graph),
        }
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    pub fn gvt(&self) -> SimTime {
        self.gvt
    }

    pub fn load_traces(&self) -> &[Trace] {
        &self.load_traces
    }

    pub fn epoch_counters(&self) -> &EpochCounters {
        &self.epoch
    }

    pub fn take_epoch_counters(&mut self) -> EpochCounters {
        let fresh = EpochCounters::for_graph(self.graph);
        std::mem::replace(&mut self.epoch, fresh)
    }

    pub fn set_partition(&mut self, part: Partition) {
        assert_eq!(part.node_count(), self.graph.node_count());
        self.part = part;
    }

    fn occupancy_cost(&self, k: MachineId, kind: EventKind) -> WallTime {
        let base = kind
            .base_process_time(self.options.base_process_time, self.options.rollback_process_time);
        let resident = self.part.count(k) as f64;
        let speed_scale = self.machines.speed(k) * self.machines.count() as f64;
        ((resident * base as f64 / speed_scale).ceil() as WallTime).max(1)
    }

    fn transfer_delay(&self, from: NodeId, to: NodeId) -> WallTime {
        if self.part.machine_of(from) == self.part.machine_of(to) {
            self.options.intra_machine_delay
        } else {
            self.options.inter_machine_delay
        }
    }

    fn compute_gvt(&self) -> SimTime {
        let mut gvt = SimTime::MAX;
        for lp in &self.lps {
            if let Some((ev, _)) = &lp.busy {
                gvt = gvt.min(ev.time);
            }
            if let Some(t) = lp.min_pending_time() {
                gvt = gvt.min(t);
            }
        }
        for inj in &self.injections {
            gvt = gvt.min(inj.event.time);
        }
        if gvt == SimTime::MAX {
            self.lps.iter().map(|l| l.local_time).max().unwrap_or(0)
        } else {
            gvt
        }
    }

    fn record_loads(&mut self) {
        let k = self.machines.count();
        let mut sums = vec![0.0f64; k];
        for (i, lp) in self.lps.iter().enumerate() {
            sums[self.part.machine_of(i)] += lp.pending.len() as f64;
        }
        for m in 0..k {
            let cnt = self.part.count(m).max(1) as f64;
            self.load_traces[m].push(self.stats.ticks as f64, sums[m] / cnt);
        }
    }

    pub fn drained(&self) -> bool {
        self.injections.is_empty() && self.lps.iter().all(|lp| lp.idle_and_empty())
    }

    /// Execute one wall-clock tick. Returns `false` once drained.
    pub fn step(&mut self) -> bool {
        if self.drained() {
            return false;
        }
        let tick = self.stats.ticks;
        let n = self.graph.node_count();

        // Injections due this tick.
        while let Some(inj) = self.injections.last().copied() {
            if inj.at_tick > tick {
                break;
            }
            self.injections.pop();
            self.lps[inj.lp].receive(inj.event);
        }

        let mut outbox_cancel: Vec<(NodeId, Event)> = Vec::new();
        let mut outbox_fwd: Vec<(NodeId, Event)> = Vec::new();

        // Start phase: idle LPs select + start, ascending.
        for i in 0..n {
            if self.lps[i].busy.is_some() {
                continue;
            }
            let Some(idx) = self.lps[i].next_ready() else { continue };
            let machine = self.part.machine_of(i);
            let ev = self.lps[i].pending.swap_remove(idx);
            let (rolled_back, cancellations) = match ev.kind {
                EventKind::Rollback => {
                    let r = self.lps[i].process_rollback(ev, self.options.inter_machine_delay);
                    let cost = self.occupancy_cost(machine, EventKind::Rollback).max(1);
                    self.lps[i].busy = Some((ev, cost));
                    r
                }
                _ => {
                    let r = if ev.time < self.lps[i].local_time {
                        self.lps[i].rollback_to(ev.time, self.options.inter_machine_delay)
                    } else {
                        (0, Vec::new())
                    };
                    self.lps[i].local_time = self.lps[i].local_time.max(ev.time);
                    let cost = self.occupancy_cost(machine, ev.kind).max(1);
                    self.lps[i].busy = Some((ev, cost));
                    r
                }
            };
            if rolled_back > 0 {
                self.epoch.rollbacks_by_lp[i] += 1;
                self.stats.rollbacks += 1;
            }
            self.stats.antimessages_sent += cancellations.len() as u64;
            for (nb, ev) in cancellations {
                let mut ev = ev;
                ev.tick = self.transfer_delay(i, nb);
                outbox_cancel.push((nb, ev));
            }
        }

        // Completion phase: busy LPs tick down; completed forwarding
        // events flood to unseen neighbors. `seen` was last written in
        // the start phase, so these reads are order-independent.
        for i in 0..n {
            let mut done = None;
            if let Some((ev, remaining)) = self.lps[i].busy.as_mut() {
                *remaining -= 1;
                if *remaining == 0 {
                    done = Some(*ev);
                }
            }
            if done.is_some() {
                self.lps[i].busy = None;
            }
            let Some(done) = done else { continue };
            self.stats.events_processed += 1;
            self.epoch.events_by_lp[i] += 1;
            if done.kind == EventKind::Rollback {
                continue;
            }
            let mut forwarded_to = Vec::new();
            if done.count > 0 {
                let machine = self.part.machine_of(i);
                let row = self.graph.row_offset(i);
                for (slot, &nb) in self.graph.neighbors(i).iter().enumerate() {
                    if self.lps[nb].has_seen(done.thread) {
                        continue;
                    }
                    let delay = self.transfer_delay(i, nb);
                    outbox_fwd.push((nb, done.forwarded(self.options.hop_latency, delay)));
                    forwarded_to.push(nb);
                    self.stats.events_forwarded += 1;
                    self.epoch.forwards_by_half_edge[row + slot] += 1;
                    if self.part.machine_of(nb) != machine {
                        self.stats.cross_machine_forwards += 1;
                        self.epoch.cross_forwards_by_lp[i] += 1;
                    }
                }
            }
            self.lps[i].history.push(RefHistoryEntry { event: done, forwarded_to });
        }

        // Delivery: cancellations then forwards, ascending sender order
        // (the push order above).
        for (nb, ev) in outbox_cancel.into_iter().chain(outbox_fwd) {
            if ev.kind != EventKind::Rollback && self.lps[nb].has_seen(ev.thread) {
                continue;
            }
            self.lps[nb].receive(ev);
        }

        // Epilogue: delays tick down, GVT advances, fossils collect.
        for lp in &mut self.lps {
            lp.tick_delays();
        }
        self.gvt = self.compute_gvt();
        for lp in &mut self.lps {
            lp.fossil_collect(self.gvt);
        }

        self.stats.ticks += 1;
        self.epoch.ticks += 1;
        if self.options.trace_every > 0 && tick % self.options.trace_every == 0 {
            self.record_loads();
        }
        true
    }

    /// Run until drained or `max_ticks`. Returns final stats.
    pub fn run_to_completion(&mut self) -> SimStats {
        while self.stats.ticks < self.options.max_ticks {
            if !self.step() {
                break;
            }
        }
        if !self.drained() {
            self.stats.truncated = true;
        }
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn reference_drains_a_flood() {
        let mut b = GraphBuilder::with_nodes(5);
        for i in 0..4 {
            b.add_edge(i, i + 1, 1.0);
        }
        let g = b.build();
        let machines = MachineConfig::homogeneous(2);
        let part = Partition::from_assignment(&g, 2, vec![0, 0, 0, 1, 1]);
        let inj = vec![Injection { at_tick: 0, lp: 0, event: Event::injection(1, 0, 4) }];
        let mut e = ReferenceEngine::new(&g, machines, part, SimOptions::default(), inj);
        let stats = e.run_to_completion();
        assert!(!stats.truncated);
        assert_eq!(stats.events_processed, 5);
        assert_eq!(stats.events_forwarded, 4);
        assert!(e.drained());
    }
}

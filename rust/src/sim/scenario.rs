//! Scenario library: scripted *drifting* workloads for the closed
//! rebalancing loop (`sim::dynamic`), all expressed as instances of one
//! composable, serializable **schedule genome** ([`DriftSchedule`]).
//!
//! [`FloodWorkload`](crate::sim::workload::FloodWorkload) draws its hot
//! spots uniformly at random per epoch; these scenarios instead script
//! the drift so each one stresses a distinct failure mode of a frozen
//! partition (§6.1: "clusters of nodes that generate large amounts of
//! traffic over a short period, whose locations change regularly"):
//!
//! * [`ScenarioKind::HotspotShift`] — one concentrated traffic ball
//!   whose center jumps to a far-away region every phase, so whatever
//!   machine hosted the old hot spot goes cold and a new one saturates.
//! * [`ScenarioKind::FlashCrowd`] — low uniform background traffic with
//!   a sudden mid-run burst into one small region (a flash crowd), the
//!   worst case for a partition balanced on the opening load.
//! * [`ScenarioKind::DiurnalRamp`] — intensity ramps up to a peak and
//!   back down while the busy region rotates, a day/night cycle over
//!   geographic regions.
//! * [`ScenarioKind::FailureRejoin`] — two persistent traffic sources;
//!   one fails mid-run (its share shifting onto the survivor) and later
//!   rejoins, exercising rebalance-twice behavior.
//!
//! Each scenario builder emits a [`DriftSchedule`]: an ordered sequence
//! of [`DriftGene`]s (windowed, parameterized drift events — hotspot
//! balls, topology-correlated surge rings, uniform background, noise
//! bursts) that [`DriftSchedule::compile`] turns into a deterministic
//! injection schedule. The genome is what `sim::fuzz` mutates, shrinks,
//! and persists as JSON: adversarial schedules found by search live in
//! the same representation as the hand-written library.
//!
//! Every schedule is deterministic given its seed: each gene draws from
//! an independent, content-addressed RNG stream
//! ([`Pcg32::derive`]), so deleting or reordering one gene never
//! perturbs the injections of another — the property delta-debug
//! shrinking relies on.

use crate::graph::{metrics, Graph, NodeId};
use crate::sim::engine::Injection;
use crate::sim::event::Event;
use crate::util::bench::JsonVal;
use crate::util::rng::Pcg32;

/// Which drifting workload to script.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    HotspotShift,
    FlashCrowd,
    DiurnalRamp,
    FailureRejoin,
}

impl ScenarioKind {
    /// All scenarios, in canonical order (the order the acceptance
    /// experiment sweeps them).
    pub const ALL: [ScenarioKind; 4] = [
        ScenarioKind::HotspotShift,
        ScenarioKind::FlashCrowd,
        ScenarioKind::DiurnalRamp,
        ScenarioKind::FailureRejoin,
    ];

    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ScenarioKind::HotspotShift => "hotspot",
            ScenarioKind::FlashCrowd => "flash",
            ScenarioKind::DiurnalRamp => "diurnal",
            ScenarioKind::FailureRejoin => "failure",
        }
    }

    /// One-line description for reports.
    pub fn describe(self) -> &'static str {
        match self {
            ScenarioKind::HotspotShift => "hot spot jumps to a far region every phase",
            ScenarioKind::FlashCrowd => "uniform background + mid-run burst into one region",
            ScenarioKind::DiurnalRamp => "intensity ramps up/down while the busy region rotates",
            ScenarioKind::FailureRejoin => "one of two traffic sources fails mid-run, then rejoins",
        }
    }

    /// The scenario's schedule genome plus its concentrated-region
    /// timeline (kept for analysis and plotting). Deterministic in
    /// `rng`; `sim::fuzz` seeds its search population from exactly
    /// these genomes.
    pub fn genome(
        self,
        g: &Graph,
        options: &ScenarioOptions,
        rng: &mut Pcg32,
    ) -> (DriftSchedule, Vec<Vec<NodeId>>) {
        match self {
            ScenarioKind::HotspotShift => genome_hotspot_shift(g, options, rng),
            ScenarioKind::FlashCrowd => genome_flash_crowd(g, options, rng),
            ScenarioKind::DiurnalRamp => genome_diurnal_ramp(g, options, rng),
            ScenarioKind::FailureRejoin => genome_failure_rejoin(g, options, rng),
        }
    }
}

impl std::str::FromStr for ScenarioKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hotspot" | "hotspot-shift" => Ok(ScenarioKind::HotspotShift),
            "flash" | "flash-crowd" => Ok(ScenarioKind::FlashCrowd),
            "diurnal" | "diurnal-ramp" => Ok(ScenarioKind::DiurnalRamp),
            "failure" | "failure-rejoin" => Ok(ScenarioKind::FailureRejoin),
            other => Err(format!(
                "unknown scenario {other:?} (expected hotspot|flash|diurnal|failure)"
            )),
        }
    }
}

impl std::fmt::Display for ScenarioKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Shape parameters shared by all scenarios.
#[derive(Debug, Clone)]
pub struct ScenarioOptions {
    /// Total packet-flood threads injected.
    pub threads: usize,
    /// Wall-clock horizon across which injections are spread.
    pub horizon_ticks: u64,
    /// Hop budget of each flood.
    pub hop_limit: u32,
    /// Number of drift phases across the horizon (hot-spot relocations,
    /// diurnal stations, ...).
    pub phases: usize,
    /// BFS-ball radius (hops) of a concentrated traffic region.
    pub region_radius: usize,
    /// Fraction of threads drawn from the active region(s); the rest is
    /// uniform background.
    pub hot_fraction: f64,
    /// Virtual-time rate: timestamp base = `at_tick * ts_rate`.
    pub ts_rate: f64,
    /// Uniform timestamp jitter in `[0, ts_jitter)`.
    pub ts_jitter: u64,
}

impl Default for ScenarioOptions {
    fn default() -> Self {
        ScenarioOptions {
            threads: 160,
            horizon_ticks: 2_400,
            hop_limit: 4,
            phases: 4,
            region_radius: 2,
            hot_fraction: 0.85,
            ts_rate: 0.5,
            ts_jitter: 8,
        }
    }
}

// ---------------------------------------------------------------------------
// The schedule genome
// ---------------------------------------------------------------------------

/// What kind of drift event a gene scripts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GeneKind {
    /// Concentrated traffic into the BFS ball around `center`.
    Hotspot,
    /// Topology-correlated surge: traffic lands on the *ring* of nodes
    /// at exactly `radius` hops from `center` (the ball if the ring is
    /// empty) — stresses partitions that cut a neighborhood frontier.
    Surge,
    /// Uniform background over the whole graph (region fields unused).
    Background,
    /// Weight-noise burst: uniform targets with 8× timestamp jitter, a
    /// straggler generator that provokes rollback storms.
    Noise,
}

impl GeneKind {
    pub const ALL: [GeneKind; 4] =
        [GeneKind::Hotspot, GeneKind::Surge, GeneKind::Background, GeneKind::Noise];

    pub fn name(self) -> &'static str {
        match self {
            GeneKind::Hotspot => "hotspot",
            GeneKind::Surge => "surge",
            GeneKind::Background => "background",
            GeneKind::Noise => "noise",
        }
    }

    fn rank(self) -> u64 {
        match self {
            GeneKind::Hotspot => 0,
            GeneKind::Surge => 1,
            GeneKind::Background => 2,
            GeneKind::Noise => 3,
        }
    }
}

impl std::str::FromStr for GeneKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hotspot" => Ok(GeneKind::Hotspot),
            "surge" => Ok(GeneKind::Surge),
            "background" => Ok(GeneKind::Background),
            "noise" => Ok(GeneKind::Noise),
            other => Err(format!(
                "unknown gene kind {other:?} (expected hotspot|surge|background|noise)"
            )),
        }
    }
}

impl std::fmt::Display for GeneKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One parameterized drift event. Window positions are **per-mille of
/// the horizon** so genomes stay integral (exact serialization, exact
/// replay) and transfer across horizons.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DriftGene {
    pub kind: GeneKind,
    /// Window start, per-mille of the horizon, `< 1000`.
    pub start_pm: u32,
    /// Window length, per-mille, `>= 1`, `start_pm + len_pm <= 1000`.
    pub len_pm: u32,
    /// Seed node of the affected region.
    pub center: NodeId,
    /// BFS radius of the region (`<= 8`).
    pub radius: u32,
    /// Threads this gene injects (`>= 1`).
    pub threads: u32,
    /// Per-mille of this gene's threads drawn from the region; the rest
    /// land uniformly. `<= 1000`.
    pub hot_pm: u32,
}

impl DriftGene {
    /// Wall-tick window `[lo, hi)` of this gene on `horizon` ticks.
    pub fn window(&self, horizon: u64) -> (u64, u64) {
        let lo = (horizon * self.start_pm as u64 / 1000).min(horizon - 1);
        let hi = (horizon * (self.start_pm + self.len_pm) as u64 / 1000).min(horizon);
        (lo, hi.max(lo + 1))
    }

    /// The concentrated region this gene targets (empty for uniform
    /// kinds).
    pub fn region(&self, g: &Graph) -> Vec<NodeId> {
        match self.kind {
            GeneKind::Background | GeneKind::Noise => Vec::new(),
            GeneKind::Hotspot => bfs_ball(g, self.center, self.radius as usize),
            GeneKind::Surge => {
                let d = metrics::bfs_distances(g, self.center);
                let ring: Vec<NodeId> =
                    (0..g.node_count()).filter(|&u| d[u] == self.radius as usize).collect();
                if ring.is_empty() {
                    bfs_ball(g, self.center, self.radius as usize)
                } else {
                    ring
                }
            }
        }
    }

    /// Structural validity against a graph of `nodes` LPs.
    pub fn validate(&self, nodes: usize) -> Result<(), String> {
        if self.len_pm == 0 {
            return Err("zero-length window".into());
        }
        if self.start_pm >= 1000 {
            return Err(format!("window starts past the horizon: {}", self.start_pm));
        }
        if self.start_pm as u64 + self.len_pm as u64 > 1000 {
            return Err(format!(
                "window [{}, {}) runs past the horizon",
                self.start_pm,
                self.start_pm as u64 + self.len_pm as u64
            ));
        }
        if self.threads == 0 {
            return Err("gene injects no threads".into());
        }
        if self.hot_pm > 1000 {
            return Err(format!("hot_pm {} > 1000", self.hot_pm));
        }
        if self.radius > 8 {
            return Err(format!("radius {} > 8", self.radius));
        }
        if self.center >= nodes {
            return Err(format!("center LP {} out of range (n={nodes})", self.center));
        }
        Ok(())
    }

    /// Content-addressed tag of this gene's private RNG stream
    /// (FNV-1a over all fields): identical genes share a stream,
    /// editing any field re-rolls it, and neighbors are untouched.
    fn stream_tag(&self) -> u64 {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for v in [
            self.kind.rank(),
            self.start_pm as u64,
            self.len_pm as u64,
            self.center as u64,
            self.radius as u64,
            self.threads as u64,
            self.hot_pm as u64,
        ] {
            h = (h ^ v).wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }

    /// Canonical ordering key: start first (monotone event times), then
    /// the remaining fields for a stable total order.
    fn sort_key(&self) -> (u32, u32, u64, NodeId, u32, u32, u32) {
        (
            self.start_pm,
            self.len_pm,
            self.kind.rank(),
            self.center,
            self.radius,
            self.threads,
            self.hot_pm,
        )
    }

    pub fn to_json(&self) -> JsonVal {
        JsonVal::Obj(vec![
            ("kind".into(), JsonVal::Str(self.kind.name().into())),
            ("start_pm".into(), JsonVal::Int(self.start_pm as u64)),
            ("len_pm".into(), JsonVal::Int(self.len_pm as u64)),
            ("center".into(), JsonVal::Int(self.center as u64)),
            ("radius".into(), JsonVal::Int(self.radius as u64)),
            ("threads".into(), JsonVal::Int(self.threads as u64)),
            ("hot_pm".into(), JsonVal::Int(self.hot_pm as u64)),
        ])
    }

    pub fn from_json(v: &JsonVal) -> Result<DriftGene, String> {
        let kind = v
            .get("kind")
            .and_then(JsonVal::as_str)
            .ok_or("gene: missing kind")?
            .parse::<GeneKind>()?;
        let field = |k: &str| {
            v.get(k)
                .and_then(JsonVal::as_u64)
                .ok_or_else(|| format!("gene: missing integer field {k:?}"))
        };
        Ok(DriftGene {
            kind,
            start_pm: field("start_pm")? as u32,
            len_pm: field("len_pm")? as u32,
            center: field("center")? as NodeId,
            radius: field("radius")? as u32,
            threads: field("threads")? as u32,
            hot_pm: field("hot_pm")? as u32,
        })
    }
}

/// Hard cap on a schedule's total thread budget (guards runaway
/// mutations before they reach the simulator).
pub const MAX_SCHEDULE_THREADS: u64 = 100_000;
/// Hard cap on gene count.
pub const MAX_GENES: usize = 256;

/// A composable, serializable drift workload: an ordered gene sequence
/// plus the global compilation parameters. This is the one type the
/// hand-written scenarios, the fuzzer's search space, and the persisted
/// corpus all share.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DriftSchedule {
    /// Master seed of the content-addressed per-gene streams.
    pub seed: u64,
    /// Wall-clock horizon the per-mille windows map onto.
    pub horizon_ticks: u64,
    /// Hop budget of every injected flood.
    pub hop_limit: u32,
    /// Virtual-time rate, per-mille: timestamp base =
    /// `at_tick * ts_rate_pm / 1000`.
    pub ts_rate_pm: u32,
    /// Uniform timestamp jitter in `[0, ts_jitter)` (8× for
    /// [`GeneKind::Noise`] genes).
    pub ts_jitter: u64,
    /// Drift events, sorted by `start_pm` (monotone event times).
    pub genes: Vec<DriftGene>,
}

impl DriftSchedule {
    /// An empty schedule shell carrying `options`' global parameters,
    /// seeded from `rng`.
    pub fn shell(options: &ScenarioOptions, rng: &mut Pcg32) -> DriftSchedule {
        DriftSchedule {
            seed: rng.next_u64(),
            horizon_ticks: options.horizon_ticks,
            hop_limit: options.hop_limit,
            ts_rate_pm: (options.ts_rate.clamp(0.0, 100.0) * 1000.0).round() as u32,
            ts_jitter: options.ts_jitter,
            genes: Vec::new(),
        }
    }

    /// Total threads across all genes.
    pub fn total_threads(&self) -> u64 {
        self.genes.iter().map(|g| g.threads as u64).sum()
    }

    /// Restore the canonical gene order (monotone `start_pm`). Mutation
    /// operators call this after every edit.
    pub fn sort_genes(&mut self) {
        self.genes.sort_by_key(|g| g.sort_key());
    }

    /// Structural validity against a graph of `nodes` LPs: at least one
    /// gene, every gene valid, monotone event times, bounded totals.
    pub fn validate(&self, nodes: usize) -> Result<(), String> {
        if nodes == 0 {
            return Err("empty graph".into());
        }
        if self.horizon_ticks == 0 {
            return Err("empty horizon".into());
        }
        if self.genes.is_empty() {
            return Err("schedule has no genes".into());
        }
        if self.genes.len() > MAX_GENES {
            return Err(format!("{} genes > cap {MAX_GENES}", self.genes.len()));
        }
        let mut prev_start = 0u32;
        for (i, gene) in self.genes.iter().enumerate() {
            gene.validate(nodes).map_err(|e| format!("gene {i}: {e}"))?;
            if gene.start_pm < prev_start {
                return Err(format!(
                    "gene {i} starts at {} before its predecessor's {prev_start} \
                     (event times must be monotone)",
                    gene.start_pm
                ));
            }
            prev_start = gene.start_pm;
        }
        let total = self.total_threads();
        if total > MAX_SCHEDULE_THREADS {
            return Err(format!("thread budget blown: {total} > {MAX_SCHEDULE_THREADS}"));
        }
        Ok(())
    }

    /// Compile the genome into a deterministic injection schedule over
    /// `g`. Each gene draws (window tick, target LP, timestamp jitter)
    /// from its own [`Pcg32::derive`] stream, so two compilations are
    /// identical and editing one gene never perturbs another's
    /// injections. Thread ids are assigned sequentially.
    pub fn compile(&self, g: &Graph) -> Vec<Injection> {
        self.validate(g.node_count())
            .unwrap_or_else(|e| panic!("compiling invalid drift schedule: {e}"));
        let n = g.node_count();
        let mut out: Vec<Injection> = Vec::with_capacity(self.total_threads() as usize);
        for gene in &self.genes {
            let mut rng = Pcg32::derive(self.seed, gene.stream_tag());
            let region = gene.region(g);
            let (lo, hi) = gene.window(self.horizon_ticks);
            let jitter = match gene.kind {
                GeneKind::Noise => self.ts_jitter.saturating_mul(8),
                _ => self.ts_jitter,
            };
            for _ in 0..gene.threads {
                let at_tick = tick_in(&mut rng, lo, hi);
                let hot =
                    !region.is_empty() && gene.hot_pm > 0 && rng.gen_below(1000) < gene.hot_pm;
                let lp = if hot { region[rng.index(region.len())] } else { rng.index(n) };
                let thread = out.len() as u64 + 1;
                let ts_base = at_tick.saturating_mul(self.ts_rate_pm as u64) / 1000;
                let ts = ts_base + rng.gen_range(0, jitter.max(1) - 1);
                out.push(Injection {
                    at_tick,
                    lp,
                    event: Event::injection(thread, ts, self.hop_limit),
                });
            }
        }
        out
    }

    pub fn to_json(&self) -> JsonVal {
        JsonVal::Obj(vec![
            ("seed".into(), JsonVal::Int(self.seed)),
            ("horizon_ticks".into(), JsonVal::Int(self.horizon_ticks)),
            ("hop_limit".into(), JsonVal::Int(self.hop_limit as u64)),
            ("ts_rate_pm".into(), JsonVal::Int(self.ts_rate_pm as u64)),
            ("ts_jitter".into(), JsonVal::Int(self.ts_jitter)),
            (
                "genes".into(),
                JsonVal::Arr(self.genes.iter().map(DriftGene::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(v: &JsonVal) -> Result<DriftSchedule, String> {
        let field = |k: &str| {
            v.get(k)
                .and_then(JsonVal::as_u64)
                .ok_or_else(|| format!("schedule: missing integer field {k:?}"))
        };
        let genes = v
            .get("genes")
            .and_then(JsonVal::as_arr)
            .ok_or("schedule: missing genes array")?
            .iter()
            .map(DriftGene::from_json)
            .collect::<Result<Vec<_>, String>>()?;
        Ok(DriftSchedule {
            seed: field("seed")?,
            horizon_ticks: field("horizon_ticks")?,
            hop_limit: field("hop_limit")? as u32,
            ts_rate_pm: field("ts_rate_pm")? as u32,
            ts_jitter: field("ts_jitter")?,
            genes,
        })
    }
}

/// A scripted workload: the genome it came from, the compiled injection
/// schedule, and the region timeline (kept for analysis and plotting).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub kind: ScenarioKind,
    /// The schedule genome this scenario is an instance of.
    pub schedule: DriftSchedule,
    pub injections: Vec<Injection>,
    /// Concentrated-region member sets, one per phase (interpretation is
    /// scenario-specific; see the genome builders).
    pub phase_regions: Vec<Vec<NodeId>>,
    pub horizon_ticks: u64,
}

impl Scenario {
    /// Build the scenario `kind` over `g`, deterministic in `rng`.
    pub fn build(
        kind: ScenarioKind,
        g: &Graph,
        options: &ScenarioOptions,
        rng: &mut Pcg32,
    ) -> Scenario {
        assert!(g.node_count() > 0 && options.threads > 0);
        assert!(options.phases >= 1);
        assert!(options.horizon_ticks >= 1, "empty horizon");
        let (schedule, phase_regions) = kind.genome(g, options, rng);
        let injections = schedule.compile(g);
        Scenario {
            kind,
            schedule,
            injections,
            phase_regions,
            horizon_ticks: options.horizon_ticks,
        }
    }

    /// Number of injections.
    pub fn len(&self) -> usize {
        self.injections.len()
    }

    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }
}

/// Nodes within `radius` hops of `center`.
pub fn bfs_ball(g: &Graph, center: NodeId, radius: usize) -> Vec<NodeId> {
    let d = metrics::bfs_distances(g, center);
    (0..g.node_count()).filter(|&u| d[u] <= radius).collect()
}

/// Greedy farthest-point centers: the first is random, each next center
/// maximizes its hop distance to all previously chosen ones — scripted
/// drift should *move*, not resample in place.
pub fn far_apart_centers(g: &Graph, count: usize, rng: &mut Pcg32) -> Vec<NodeId> {
    let n = g.node_count();
    let mut centers = vec![rng.index(n)];
    let mut min_dist = metrics::bfs_distances(g, centers[0]);
    while centers.len() < count {
        let next = (0..n)
            .filter(|&u| min_dist[u] != usize::MAX)
            .max_by_key(|&u| min_dist[u])
            .unwrap_or_else(|| rng.index(n));
        centers.push(next);
        let d = metrics::bfs_distances(g, next);
        for u in 0..n {
            min_dist[u] = min_dist[u].min(d[u]);
        }
    }
    centers
}

/// Uniform wall tick within `[lo, hi)`.
fn tick_in(rng: &mut Pcg32, lo: u64, hi: u64) -> u64 {
    rng.gen_range(lo, hi.max(lo + 1) - 1)
}

/// Per-mille hot fraction of `options`.
fn hot_pm_of(options: &ScenarioOptions) -> u32 {
    (options.hot_fraction.clamp(0.0, 1.0) * 1000.0).round() as u32
}

/// Split `total` threads over `parts` consecutive shares (each at least
/// one).
fn split_threads(total: usize, parts: usize) -> Vec<u32> {
    let parts = parts.max(1);
    (0..parts)
        .map(|p| {
            let lo = total * p / parts;
            let hi = total * (p + 1) / parts;
            (hi - lo).max(1) as u32
        })
        .collect()
}

/// Per-mille `(start, len)` windows tiling the horizon over `phases`
/// (shared with `sim::fuzz`'s seed template).
pub(crate) fn phase_windows(phases: usize) -> Vec<(u32, u32)> {
    (0..phases)
        .map(|p| {
            let start = (1000 * p / phases) as u32;
            let end = (1000 * (p + 1) / phases) as u32;
            (start, (end - start).max(1))
        })
        .collect()
}

fn genome_hotspot_shift(
    g: &Graph,
    options: &ScenarioOptions,
    rng: &mut Pcg32,
) -> (DriftSchedule, Vec<Vec<NodeId>>) {
    let phases = options.phases.clamp(1, 1000);
    let mut schedule = DriftSchedule::shell(options, rng);
    let centers = far_apart_centers(g, phases, rng);
    let regions: Vec<Vec<NodeId>> =
        centers.iter().map(|&c| bfs_ball(g, c, options.region_radius)).collect();
    let hot_pm = hot_pm_of(options);
    let shares = split_threads(options.threads, phases);
    let windows = phase_windows(phases);
    schedule.genes = (0..phases)
        .map(|p| DriftGene {
            kind: GeneKind::Hotspot,
            start_pm: windows[p].0,
            len_pm: windows[p].1,
            center: centers[p],
            radius: options.region_radius as u32,
            threads: shares[p],
            hot_pm,
        })
        .collect();
    (schedule, regions)
}

fn genome_flash_crowd(
    g: &Graph,
    options: &ScenarioOptions,
    rng: &mut Pcg32,
) -> (DriftSchedule, Vec<Vec<NodeId>>) {
    let mut schedule = DriftSchedule::shell(options, rng);
    let crowd_center = rng.index(g.node_count());
    let crowd = bfs_ball(g, crowd_center, options.region_radius);
    // The crowd bursts in the middle fifth of the horizon; per-mille
    // window [400, 600) is exactly the old [2/5, 3/5) tick window.
    let crowd_threads = (options.threads as f64 * options.hot_fraction * 0.7) as usize;
    let background = options.threads.saturating_sub(crowd_threads);
    schedule.genes = vec![
        DriftGene {
            kind: GeneKind::Background,
            start_pm: 0,
            len_pm: 1000,
            center: crowd_center,
            radius: 0,
            threads: background.max(1) as u32,
            hot_pm: 0,
        },
        DriftGene {
            kind: GeneKind::Hotspot,
            start_pm: 400,
            len_pm: 200,
            center: crowd_center,
            radius: options.region_radius as u32,
            threads: crowd_threads.max(1) as u32,
            hot_pm: 1000,
        },
    ];
    (schedule, vec![crowd])
}

fn genome_diurnal_ramp(
    g: &Graph,
    options: &ScenarioOptions,
    rng: &mut Pcg32,
) -> (DriftSchedule, Vec<Vec<NodeId>>) {
    let phases = options.phases.clamp(1, 1000);
    let mut schedule = DriftSchedule::shell(options, rng);
    let centers = far_apart_centers(g, phases, rng);
    let regions: Vec<Vec<NodeId>> =
        centers.iter().map(|&c| bfs_ball(g, c, options.region_radius)).collect();
    // Triangular intensity profile over phases: 1, 2, ..., peak, ..., 2, 1.
    let weights: Vec<f64> =
        (0..phases).map(|p| 1.0 + p.min(phases - 1 - p) as f64).collect();
    let total_w: f64 = weights.iter().sum();
    let windows = phase_windows(phases);
    let hot_pm = hot_pm_of(options);
    schedule.genes = (0..phases)
        .map(|p| DriftGene {
            kind: GeneKind::Hotspot,
            start_pm: windows[p].0,
            len_pm: windows[p].1,
            center: centers[p],
            radius: options.region_radius as u32,
            threads: ((options.threads as f64 * weights[p] / total_w).round() as u32).max(1),
            hot_pm,
        })
        .collect();
    (schedule, regions)
}

fn genome_failure_rejoin(
    g: &Graph,
    options: &ScenarioOptions,
    rng: &mut Pcg32,
) -> (DriftSchedule, Vec<Vec<NodeId>>) {
    let mut schedule = DriftSchedule::shell(options, rng);
    let centers = far_apart_centers(g, 2, rng);
    let (a, b) = (centers[0], centers[1]);
    let source_a = bfs_ball(g, a, options.region_radius);
    let source_b = bfs_ball(g, b, options.region_radius);
    let radius = options.region_radius as u32;
    // B is down during the middle window [350, 700)‰ — exactly the old
    // [35%, 70%) tick window; its traffic share shifts onto A (the
    // survivor absorbs the load), then B rejoins.
    let hot_total = (options.threads as f64 * options.hot_fraction) as u32;
    let background = (options.threads as u32).saturating_sub(hot_total).max(1);
    let pre = (hot_total as f64 * 0.35) as u32;
    let outage = pre;
    let post = hot_total.saturating_sub(pre + outage);
    let hot = |start_pm: u32, len_pm: u32, center: NodeId, threads: u32| DriftGene {
        kind: GeneKind::Hotspot,
        start_pm,
        len_pm,
        center,
        radius,
        threads: threads.max(1),
        hot_pm: 1000,
    };
    schedule.genes = vec![
        DriftGene {
            kind: GeneKind::Background,
            start_pm: 0,
            len_pm: 1000,
            center: a,
            radius: 0,
            threads: background,
            hot_pm: 0,
        },
        hot(0, 350, a, pre / 2),
        hot(0, 350, b, pre - pre / 2),
        hot(350, 350, a, outage),
        hot(700, 300, a, post / 2),
        hot(700, 300, b, post - post / 2),
    ];
    (schedule, vec![source_a, source_b])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::preferential_attachment;
    use crate::util::bench::parse_json;

    fn graph() -> Graph {
        let mut rng = Pcg32::new(1);
        preferential_attachment(150, 2, &mut rng)
    }

    fn build(kind: ScenarioKind, seed: u64) -> Scenario {
        let g = graph();
        let mut rng = Pcg32::new(seed);
        Scenario::build(kind, &g, &ScenarioOptions::default(), &mut rng)
    }

    #[test]
    fn all_scenarios_generate_valid_schedules() {
        let g = graph();
        let opts = ScenarioOptions::default();
        for kind in ScenarioKind::ALL {
            let mut rng = Pcg32::new(3);
            let s = Scenario::build(kind, &g, &opts, &mut rng);
            assert!(!s.is_empty(), "{kind}: empty schedule");
            s.schedule.validate(g.node_count()).unwrap_or_else(|e| panic!("{kind}: {e}"));
            let mut threads: Vec<u64> =
                s.injections.iter().map(|i| i.event.thread).collect();
            threads.sort_unstable();
            threads.dedup();
            assert_eq!(threads.len(), s.len(), "{kind}: duplicate thread ids");
            for inj in &s.injections {
                assert!(inj.at_tick < opts.horizon_ticks, "{kind}: beyond horizon");
                assert!(inj.lp < g.node_count(), "{kind}: LP out of range");
                assert_eq!(inj.event.count, opts.hop_limit);
            }
            assert!(!s.phase_regions.is_empty());
        }
    }

    #[test]
    fn scenarios_are_deterministic_per_seed() {
        for kind in ScenarioKind::ALL {
            let a = build(kind, 7);
            let b = build(kind, 7);
            assert_eq!(a.schedule, b.schedule, "{kind}: genome differs across builds");
            assert_eq!(a.injections.len(), b.injections.len());
            for (x, y) in a.injections.iter().zip(&b.injections) {
                assert_eq!((x.at_tick, x.lp, x.event), (y.at_tick, y.lp, y.event));
            }
            let c = build(kind, 8);
            let same = a.len() == c.len()
                && a.injections
                    .iter()
                    .zip(&c.injections)
                    .all(|(x, y)| (x.at_tick, x.lp) == (y.at_tick, y.lp));
            assert!(!same, "{kind}: seed does not matter?");
        }
    }

    #[test]
    fn hotspot_shift_moves_between_phases() {
        let s = build(ScenarioKind::HotspotShift, 11);
        assert_eq!(s.phase_regions.len(), ScenarioOptions::default().phases);
        // Consecutive regions must differ (the whole point of the drift).
        for pair in s.phase_regions.windows(2) {
            assert_ne!(pair[0], pair[1], "hot spot did not move");
        }
    }

    #[test]
    fn flash_crowd_concentrates_in_burst_window() {
        let opts = ScenarioOptions::default();
        let s = build(ScenarioKind::FlashCrowd, 13);
        let crowd = &s.phase_regions[0];
        let burst_lo = opts.horizon_ticks * 2 / 5;
        let burst_hi = opts.horizon_ticks * 3 / 5;
        let in_burst = s
            .injections
            .iter()
            .filter(|i| i.at_tick >= burst_lo && i.at_tick < burst_hi)
            .count();
        let in_crowd = s.injections.iter().filter(|i| crowd.contains(&i.lp)).count();
        // The burst window is 20% of the horizon but holds over 40% of
        // the traffic, concentrated inside the crowd ball.
        assert!(
            in_burst as f64 > 0.4 * s.len() as f64,
            "burst too weak: {in_burst}/{}",
            s.len()
        );
        assert!(
            in_crowd as f64 > 0.4 * s.len() as f64,
            "crowd too diffuse: {in_crowd}/{}",
            s.len()
        );
    }

    #[test]
    fn diurnal_ramp_peaks_mid_horizon() {
        let opts = ScenarioOptions::default();
        let s = build(ScenarioKind::DiurnalRamp, 17);
        let phase_len = opts.horizon_ticks / opts.phases as u64;
        let mut per_phase = vec![0usize; opts.phases];
        for inj in &s.injections {
            per_phase[((inj.at_tick / phase_len) as usize).min(opts.phases - 1)] += 1;
        }
        let peak: usize = per_phase[1].max(per_phase[2]);
        assert!(
            peak > per_phase[0] && peak > per_phase[opts.phases - 1],
            "no mid-horizon peak: {per_phase:?}"
        );
    }

    #[test]
    fn failure_rejoin_shifts_load_to_survivor() {
        let opts = ScenarioOptions::default();
        let s = build(ScenarioKind::FailureRejoin, 19);
        let a = &s.phase_regions[0];
        let b = &s.phase_regions[1];
        let down_lo = opts.horizon_ticks * 35 / 100;
        let down_hi = opts.horizon_ticks * 70 / 100;
        let b_during_outage = s
            .injections
            .iter()
            .filter(|i| i.at_tick >= down_lo && i.at_tick < down_hi)
            .filter(|i| b.contains(&i.lp) && !a.contains(&i.lp))
            .count();
        let a_during_outage = s
            .injections
            .iter()
            .filter(|i| i.at_tick >= down_lo && i.at_tick < down_hi)
            .filter(|i| a.contains(&i.lp))
            .count();
        assert!(
            a_during_outage > 3 * b_during_outage.max(1),
            "survivor did not absorb the failed source's load: A={a_during_outage} B={b_during_outage}"
        );
        // B is active again after the outage.
        let b_after = s
            .injections
            .iter()
            .filter(|i| i.at_tick >= down_hi)
            .filter(|i| b.contains(&i.lp))
            .count();
        assert!(b_after > 0, "B never rejoined");
    }

    #[test]
    fn kind_round_trips_through_strings() {
        for kind in ScenarioKind::ALL {
            let parsed: ScenarioKind = kind.name().parse().unwrap();
            assert_eq!(parsed, kind);
        }
        assert!("bogus".parse::<ScenarioKind>().is_err());
        for kind in GeneKind::ALL {
            let parsed: GeneKind = kind.name().parse().unwrap();
            assert_eq!(parsed, kind);
        }
        assert!("bogus".parse::<GeneKind>().is_err());
    }

    // ------------------------------------------------------------------
    // Genome-level tests
    // ------------------------------------------------------------------

    fn sample_schedule(seed: u64) -> DriftSchedule {
        DriftSchedule {
            seed,
            horizon_ticks: 900,
            hop_limit: 4,
            ts_rate_pm: 500,
            ts_jitter: 8,
            genes: vec![
                DriftGene {
                    kind: GeneKind::Background,
                    start_pm: 0,
                    len_pm: 1000,
                    center: 0,
                    radius: 0,
                    threads: 20,
                    hot_pm: 0,
                },
                DriftGene {
                    kind: GeneKind::Hotspot,
                    start_pm: 100,
                    len_pm: 300,
                    center: 42,
                    radius: 1,
                    threads: 30,
                    hot_pm: 1000,
                },
                DriftGene {
                    kind: GeneKind::Surge,
                    start_pm: 500,
                    len_pm: 250,
                    center: 97,
                    radius: 2,
                    threads: 25,
                    hot_pm: 900,
                },
                DriftGene {
                    kind: GeneKind::Noise,
                    start_pm: 800,
                    len_pm: 200,
                    center: 0,
                    radius: 0,
                    threads: 10,
                    hot_pm: 0,
                },
            ],
        }
    }

    #[test]
    fn compile_is_deterministic_and_valid() {
        let g = graph();
        let s = sample_schedule(99);
        s.validate(g.node_count()).unwrap();
        let a = s.compile(&g);
        let b = s.compile(&g);
        assert_eq!(a.len() as u64, s.total_threads());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.at_tick, x.lp, x.event), (y.at_tick, y.lp, y.event));
        }
        for inj in &a {
            assert!(inj.at_tick < s.horizon_ticks);
            assert!(inj.lp < g.node_count());
        }
    }

    #[test]
    fn gene_streams_are_deletion_independent() {
        let g = graph();
        let full = sample_schedule(7);
        let full_inj = full.compile(&g);
        // Drop the hotspot gene: every other gene's injections must be
        // unchanged modulo thread-id renumbering.
        let mut pruned = full.clone();
        pruned.genes.remove(1);
        let pruned_inj = pruned.compile(&g);
        let key = |i: &Injection| (i.at_tick, i.lp, i.event.time, i.event.count);
        let survivors: Vec<_> = full_inj[..20]
            .iter()
            .chain(&full_inj[50..])
            .map(key)
            .collect();
        let pruned_keys: Vec<_> = pruned_inj.iter().map(key).collect();
        assert_eq!(survivors, pruned_keys, "deleting one gene perturbed another");
    }

    #[test]
    fn schedule_json_round_trips_exactly() {
        let s = sample_schedule(u64::MAX - 17);
        let text = s.to_json().render();
        let back = DriftSchedule::from_json(&parse_json(&text).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn validate_rejects_malformed_genomes() {
        let g = graph();
        let n = g.node_count();
        let good = sample_schedule(1);
        good.validate(n).unwrap();
        let mut empty = good.clone();
        empty.genes.clear();
        assert!(empty.validate(n).is_err(), "empty gene list accepted");
        let mut non_monotone = good.clone();
        non_monotone.genes.swap(1, 2);
        assert!(non_monotone.validate(n).is_err(), "non-monotone starts accepted");
        let mut oob = good.clone();
        oob.genes[1].center = n;
        assert!(oob.validate(n).is_err(), "out-of-range center accepted");
        let mut overhang = good.clone();
        overhang.genes[1].start_pm = 900;
        overhang.genes[1].len_pm = 200;
        overhang.sort_genes();
        assert!(overhang.validate(n).is_err(), "window past horizon accepted");
        let mut dead = good.clone();
        dead.genes[1].threads = 0;
        assert!(dead.validate(n).is_err(), "zero-thread gene accepted");
    }

    #[test]
    fn windows_stay_inside_the_horizon() {
        for horizon in [1u64, 7, 900, 2_400] {
            for (start, len) in [(0u32, 1u32), (0, 1000), (999, 1), (400, 200), (750, 250)] {
                let gene = DriftGene {
                    kind: GeneKind::Hotspot,
                    start_pm: start,
                    len_pm: len,
                    center: 0,
                    radius: 0,
                    threads: 1,
                    hot_pm: 0,
                };
                let (lo, hi) = gene.window(horizon);
                assert!(lo < hi, "empty window for {start}+{len} on {horizon}");
                assert!(hi <= horizon.max(lo + 1), "window past horizon");
                assert!(lo < horizon, "window starts past horizon");
            }
        }
    }

    #[test]
    fn scenario_genomes_round_trip_through_json() {
        let g = graph();
        for kind in ScenarioKind::ALL {
            let mut rng = Pcg32::new(23);
            let (schedule, _) = kind.genome(&g, &ScenarioOptions::default(), &mut rng);
            let text = schedule.to_json().render();
            let back = DriftSchedule::from_json(&parse_json(&text).unwrap()).unwrap();
            assert_eq!(back, schedule, "{kind}: genome JSON round trip drifted");
            let a = schedule.compile(&g);
            let b = back.compile(&g);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!((x.at_tick, x.lp, x.event), (y.at_tick, y.lp, y.event));
            }
        }
    }
}

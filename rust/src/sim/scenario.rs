//! Scenario library: scripted *drifting* workloads for the closed
//! rebalancing loop (`sim::dynamic`).
//!
//! [`FloodWorkload`](crate::sim::workload::FloodWorkload) draws its hot
//! spots uniformly at random per epoch; these scenarios instead script
//! the drift so each one stresses a distinct failure mode of a frozen
//! partition (§6.1: "clusters of nodes that generate large amounts of
//! traffic over a short period, whose locations change regularly"):
//!
//! * [`ScenarioKind::HotspotShift`] — one concentrated traffic ball
//!   whose center jumps to a far-away region every phase, so whatever
//!   machine hosted the old hot spot goes cold and a new one saturates.
//! * [`ScenarioKind::FlashCrowd`] — low uniform background traffic with
//!   a sudden mid-run burst into one small region (a flash crowd), the
//!   worst case for a partition balanced on the opening load.
//! * [`ScenarioKind::DiurnalRamp`] — intensity ramps up to a peak and
//!   back down while the busy region rotates, a day/night cycle over
//!   geographic regions.
//! * [`ScenarioKind::FailureRejoin`] — two persistent traffic sources;
//!   one fails mid-run (its share shifting onto the survivor) and later
//!   rejoins, exercising rebalance-twice behavior.
//!
//! Every scenario is deterministic given the seed RNG and spreads the
//! same total thread budget across the same horizon, so frozen vs
//! rebalanced runs and different estimators compare like-for-like.

use crate::graph::{metrics, Graph, NodeId};
use crate::sim::engine::Injection;
use crate::sim::event::Event;
use crate::util::rng::Pcg32;

/// Which drifting workload to script.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    HotspotShift,
    FlashCrowd,
    DiurnalRamp,
    FailureRejoin,
}

impl ScenarioKind {
    /// All scenarios, in canonical order (the order the acceptance
    /// experiment sweeps them).
    pub const ALL: [ScenarioKind; 4] = [
        ScenarioKind::HotspotShift,
        ScenarioKind::FlashCrowd,
        ScenarioKind::DiurnalRamp,
        ScenarioKind::FailureRejoin,
    ];

    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ScenarioKind::HotspotShift => "hotspot",
            ScenarioKind::FlashCrowd => "flash",
            ScenarioKind::DiurnalRamp => "diurnal",
            ScenarioKind::FailureRejoin => "failure",
        }
    }

    /// One-line description for reports.
    pub fn describe(self) -> &'static str {
        match self {
            ScenarioKind::HotspotShift => "hot spot jumps to a far region every phase",
            ScenarioKind::FlashCrowd => "uniform background + mid-run burst into one region",
            ScenarioKind::DiurnalRamp => "intensity ramps up/down while the busy region rotates",
            ScenarioKind::FailureRejoin => "one of two traffic sources fails mid-run, then rejoins",
        }
    }
}

impl std::str::FromStr for ScenarioKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hotspot" | "hotspot-shift" => Ok(ScenarioKind::HotspotShift),
            "flash" | "flash-crowd" => Ok(ScenarioKind::FlashCrowd),
            "diurnal" | "diurnal-ramp" => Ok(ScenarioKind::DiurnalRamp),
            "failure" | "failure-rejoin" => Ok(ScenarioKind::FailureRejoin),
            other => Err(format!(
                "unknown scenario {other:?} (expected hotspot|flash|diurnal|failure)"
            )),
        }
    }
}

impl std::fmt::Display for ScenarioKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Shape parameters shared by all scenarios.
#[derive(Debug, Clone)]
pub struct ScenarioOptions {
    /// Total packet-flood threads injected.
    pub threads: usize,
    /// Wall-clock horizon across which injections are spread.
    pub horizon_ticks: u64,
    /// Hop budget of each flood.
    pub hop_limit: u32,
    /// Number of drift phases across the horizon (hot-spot relocations,
    /// diurnal stations, ...).
    pub phases: usize,
    /// BFS-ball radius (hops) of a concentrated traffic region.
    pub region_radius: usize,
    /// Fraction of threads drawn from the active region(s); the rest is
    /// uniform background.
    pub hot_fraction: f64,
    /// Virtual-time rate: timestamp base = `at_tick * ts_rate`.
    pub ts_rate: f64,
    /// Uniform timestamp jitter in `[0, ts_jitter)`.
    pub ts_jitter: u64,
}

impl Default for ScenarioOptions {
    fn default() -> Self {
        ScenarioOptions {
            threads: 160,
            horizon_ticks: 2_400,
            hop_limit: 4,
            phases: 4,
            region_radius: 2,
            hot_fraction: 0.85,
            ts_rate: 0.5,
            ts_jitter: 8,
        }
    }
}

/// A scripted workload: the injection schedule plus the region timeline
/// (kept for analysis and plotting).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub kind: ScenarioKind,
    pub injections: Vec<Injection>,
    /// Concentrated-region member sets, one per phase (interpretation is
    /// scenario-specific; see the builders).
    pub phase_regions: Vec<Vec<NodeId>>,
    pub horizon_ticks: u64,
}

impl Scenario {
    /// Build the scenario `kind` over `g`, deterministic in `rng`.
    pub fn build(
        kind: ScenarioKind,
        g: &Graph,
        options: &ScenarioOptions,
        rng: &mut Pcg32,
    ) -> Scenario {
        assert!(g.node_count() > 0 && options.threads > 0);
        assert!(options.phases >= 1);
        assert!(options.horizon_ticks >= 1, "empty horizon");
        match kind {
            ScenarioKind::HotspotShift => build_hotspot_shift(g, options, rng),
            ScenarioKind::FlashCrowd => build_flash_crowd(g, options, rng),
            ScenarioKind::DiurnalRamp => build_diurnal_ramp(g, options, rng),
            ScenarioKind::FailureRejoin => build_failure_rejoin(g, options, rng),
        }
    }

    /// Number of injections.
    pub fn len(&self) -> usize {
        self.injections.len()
    }

    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }
}

/// Nodes within `radius` hops of `center`.
fn bfs_ball(g: &Graph, center: NodeId, radius: usize) -> Vec<NodeId> {
    let d = metrics::bfs_distances(g, center);
    (0..g.node_count()).filter(|&u| d[u] <= radius).collect()
}

/// Greedy farthest-point centers: the first is random, each next center
/// maximizes its hop distance to all previously chosen ones — scripted
/// drift should *move*, not resample in place.
fn far_apart_centers(g: &Graph, count: usize, rng: &mut Pcg32) -> Vec<NodeId> {
    let n = g.node_count();
    let mut centers = vec![rng.index(n)];
    let mut min_dist = metrics::bfs_distances(g, centers[0]);
    while centers.len() < count {
        let next = (0..n)
            .filter(|&u| min_dist[u] != usize::MAX)
            .max_by_key(|&u| min_dist[u])
            .unwrap_or_else(|| rng.index(n));
        centers.push(next);
        let d = metrics::bfs_distances(g, next);
        for u in 0..n {
            min_dist[u] = min_dist[u].min(d[u]);
        }
    }
    centers
}

/// Push one injection, drawing a jittered virtual timestamp coupled to
/// the wall-clock arrival (as `sim::workload` does).
fn inject(
    out: &mut Vec<Injection>,
    options: &ScenarioOptions,
    rng: &mut Pcg32,
    lp: NodeId,
    at_tick: u64,
) {
    let thread = out.len() as u64 + 1;
    let ts_base = (at_tick as f64 * options.ts_rate) as u64;
    // gen_range is inclusive on both ends: jitter lands in [0, ts_jitter).
    let ts = ts_base + rng.gen_range(0, options.ts_jitter.max(1) - 1);
    out.push(Injection {
        at_tick,
        lp,
        event: Event::injection(thread, ts, options.hop_limit),
    });
}

/// Uniform wall tick within `[lo, hi)`.
fn tick_in(rng: &mut Pcg32, lo: u64, hi: u64) -> u64 {
    rng.gen_range(lo, hi.max(lo + 1) - 1)
}

fn build_hotspot_shift(g: &Graph, options: &ScenarioOptions, rng: &mut Pcg32) -> Scenario {
    let n = g.node_count();
    let centers = far_apart_centers(g, options.phases, rng);
    let phase_regions: Vec<Vec<NodeId>> =
        centers.iter().map(|&c| bfs_ball(g, c, options.region_radius)).collect();
    let phase_len = (options.horizon_ticks / options.phases as u64).max(1);

    let mut injections = Vec::with_capacity(options.threads);
    for _ in 0..options.threads {
        let at_tick = tick_in(rng, 0, options.horizon_ticks);
        let phase = ((at_tick / phase_len) as usize).min(options.phases - 1);
        let lp = if rng.chance(options.hot_fraction) {
            let region = &phase_regions[phase];
            region[rng.index(region.len())]
        } else {
            rng.index(n)
        };
        inject(&mut injections, options, rng, lp, at_tick);
    }
    Scenario {
        kind: ScenarioKind::HotspotShift,
        injections,
        phase_regions,
        horizon_ticks: options.horizon_ticks,
    }
}

fn build_flash_crowd(g: &Graph, options: &ScenarioOptions, rng: &mut Pcg32) -> Scenario {
    let n = g.node_count();
    let crowd_center = rng.index(n);
    let crowd = bfs_ball(g, crowd_center, options.region_radius);
    // The crowd bursts in the middle fifth of the horizon.
    let burst_lo = options.horizon_ticks * 2 / 5;
    let burst_hi = options.horizon_ticks * 3 / 5;
    let crowd_threads = (options.threads as f64 * options.hot_fraction * 0.7) as usize;

    let mut injections = Vec::with_capacity(options.threads);
    for t in 0..options.threads {
        if t < crowd_threads {
            let at_tick = tick_in(rng, burst_lo, burst_hi);
            let lp = crowd[rng.index(crowd.len())];
            inject(&mut injections, options, rng, lp, at_tick);
        } else {
            let at_tick = tick_in(rng, 0, options.horizon_ticks);
            let lp = rng.index(n);
            inject(&mut injections, options, rng, lp, at_tick);
        }
    }
    Scenario {
        kind: ScenarioKind::FlashCrowd,
        injections,
        phase_regions: vec![crowd],
        horizon_ticks: options.horizon_ticks,
    }
}

fn build_diurnal_ramp(g: &Graph, options: &ScenarioOptions, rng: &mut Pcg32) -> Scenario {
    let n = g.node_count();
    let centers = far_apart_centers(g, options.phases, rng);
    let phase_regions: Vec<Vec<NodeId>> =
        centers.iter().map(|&c| bfs_ball(g, c, options.region_radius)).collect();
    let phase_len = (options.horizon_ticks / options.phases as u64).max(1);

    // Triangular intensity profile over phases: 1, 2, ..., peak, ..., 2, 1.
    let weights: Vec<f64> = (0..options.phases)
        .map(|p| 1.0 + p.min(options.phases - 1 - p) as f64)
        .collect();
    let total_w: f64 = weights.iter().sum();

    let mut injections = Vec::with_capacity(options.threads);
    for (phase, w) in weights.iter().enumerate() {
        let share = ((options.threads as f64) * w / total_w).round() as usize;
        // Clamp the phase window inside the horizon: with more phases
        // than ticks the trailing windows would otherwise start at (or
        // past) the horizon and inject out-of-range ticks.
        let lo = (phase as u64 * phase_len).min(options.horizon_ticks - 1);
        let hi = if phase + 1 == options.phases {
            options.horizon_ticks
        } else {
            (lo + phase_len).min(options.horizon_ticks)
        };
        for _ in 0..share.max(1) {
            let at_tick = tick_in(rng, lo, hi);
            let lp = if rng.chance(options.hot_fraction) {
                let region = &phase_regions[phase];
                region[rng.index(region.len())]
            } else {
                rng.index(n)
            };
            inject(&mut injections, options, rng, lp, at_tick);
        }
    }
    Scenario {
        kind: ScenarioKind::DiurnalRamp,
        injections,
        phase_regions,
        horizon_ticks: options.horizon_ticks,
    }
}

fn build_failure_rejoin(g: &Graph, options: &ScenarioOptions, rng: &mut Pcg32) -> Scenario {
    let n = g.node_count();
    let centers = far_apart_centers(g, 2, rng);
    let source_a = bfs_ball(g, centers[0], options.region_radius);
    let source_b = bfs_ball(g, centers[1], options.region_radius);
    // B is down during the middle window [35%, 70%); its traffic share
    // shifts onto A (the survivor absorbs the load), then B rejoins.
    let down_lo = options.horizon_ticks * 35 / 100;
    let down_hi = options.horizon_ticks * 70 / 100;

    let mut injections = Vec::with_capacity(options.threads);
    for _ in 0..options.threads {
        let at_tick = tick_in(rng, 0, options.horizon_ticks);
        let b_down = at_tick >= down_lo && at_tick < down_hi;
        let lp = if rng.chance(options.hot_fraction) {
            let region = if b_down || rng.chance(0.5) { &source_a } else { &source_b };
            region[rng.index(region.len())]
        } else {
            rng.index(n)
        };
        inject(&mut injections, options, rng, lp, at_tick);
    }
    Scenario {
        kind: ScenarioKind::FailureRejoin,
        injections,
        phase_regions: vec![source_a, source_b],
        horizon_ticks: options.horizon_ticks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::preferential_attachment;

    fn graph() -> Graph {
        let mut rng = Pcg32::new(1);
        preferential_attachment(150, 2, &mut rng)
    }

    fn build(kind: ScenarioKind, seed: u64) -> Scenario {
        let g = graph();
        let mut rng = Pcg32::new(seed);
        Scenario::build(kind, &g, &ScenarioOptions::default(), &mut rng)
    }

    #[test]
    fn all_scenarios_generate_valid_schedules() {
        let g = graph();
        let opts = ScenarioOptions::default();
        for kind in ScenarioKind::ALL {
            let mut rng = Pcg32::new(3);
            let s = Scenario::build(kind, &g, &opts, &mut rng);
            assert!(!s.is_empty(), "{kind}: empty schedule");
            let mut threads: Vec<u64> =
                s.injections.iter().map(|i| i.event.thread).collect();
            threads.sort_unstable();
            threads.dedup();
            assert_eq!(threads.len(), s.len(), "{kind}: duplicate thread ids");
            for inj in &s.injections {
                assert!(inj.at_tick < opts.horizon_ticks, "{kind}: beyond horizon");
                assert!(inj.lp < g.node_count(), "{kind}: LP out of range");
                assert_eq!(inj.event.count, opts.hop_limit);
            }
            assert!(!s.phase_regions.is_empty());
        }
    }

    #[test]
    fn scenarios_are_deterministic_per_seed() {
        for kind in ScenarioKind::ALL {
            let a = build(kind, 7);
            let b = build(kind, 7);
            assert_eq!(a.injections.len(), b.injections.len());
            for (x, y) in a.injections.iter().zip(&b.injections) {
                assert_eq!((x.at_tick, x.lp, x.event), (y.at_tick, y.lp, y.event));
            }
            let c = build(kind, 8);
            let same = a.len() == c.len()
                && a.injections
                    .iter()
                    .zip(&c.injections)
                    .all(|(x, y)| (x.at_tick, x.lp) == (y.at_tick, y.lp));
            assert!(!same, "{kind}: seed does not matter?");
        }
    }

    #[test]
    fn hotspot_shift_moves_between_phases() {
        let s = build(ScenarioKind::HotspotShift, 11);
        assert_eq!(s.phase_regions.len(), ScenarioOptions::default().phases);
        // Consecutive regions must differ (the whole point of the drift).
        for pair in s.phase_regions.windows(2) {
            assert_ne!(pair[0], pair[1], "hot spot did not move");
        }
    }

    #[test]
    fn flash_crowd_concentrates_in_burst_window() {
        let opts = ScenarioOptions::default();
        let s = build(ScenarioKind::FlashCrowd, 13);
        let crowd = &s.phase_regions[0];
        let burst_lo = opts.horizon_ticks * 2 / 5;
        let burst_hi = opts.horizon_ticks * 3 / 5;
        let in_burst = s
            .injections
            .iter()
            .filter(|i| i.at_tick >= burst_lo && i.at_tick < burst_hi)
            .count();
        let in_crowd = s.injections.iter().filter(|i| crowd.contains(&i.lp)).count();
        // The burst window is 20% of the horizon but holds over 40% of
        // the traffic, concentrated inside the crowd ball.
        assert!(
            in_burst as f64 > 0.4 * s.len() as f64,
            "burst too weak: {in_burst}/{}",
            s.len()
        );
        assert!(
            in_crowd as f64 > 0.4 * s.len() as f64,
            "crowd too diffuse: {in_crowd}/{}",
            s.len()
        );
    }

    #[test]
    fn diurnal_ramp_peaks_mid_horizon() {
        let opts = ScenarioOptions::default();
        let s = build(ScenarioKind::DiurnalRamp, 17);
        let phase_len = opts.horizon_ticks / opts.phases as u64;
        let mut per_phase = vec![0usize; opts.phases];
        for inj in &s.injections {
            per_phase[((inj.at_tick / phase_len) as usize).min(opts.phases - 1)] += 1;
        }
        let peak: usize = per_phase[1].max(per_phase[2]);
        assert!(
            peak > per_phase[0] && peak > per_phase[opts.phases - 1],
            "no mid-horizon peak: {per_phase:?}"
        );
    }

    #[test]
    fn failure_rejoin_shifts_load_to_survivor() {
        let opts = ScenarioOptions::default();
        let s = build(ScenarioKind::FailureRejoin, 19);
        let a = &s.phase_regions[0];
        let b = &s.phase_regions[1];
        let down_lo = opts.horizon_ticks * 35 / 100;
        let down_hi = opts.horizon_ticks * 70 / 100;
        let b_during_outage = s
            .injections
            .iter()
            .filter(|i| i.at_tick >= down_lo && i.at_tick < down_hi)
            .filter(|i| b.contains(&i.lp) && !a.contains(&i.lp))
            .count();
        let a_during_outage = s
            .injections
            .iter()
            .filter(|i| i.at_tick >= down_lo && i.at_tick < down_hi)
            .filter(|i| a.contains(&i.lp))
            .count();
        assert!(
            a_during_outage > 3 * b_during_outage.max(1),
            "survivor did not absorb the failed source's load: A={a_during_outage} B={b_during_outage}"
        );
        // B is active again after the outage.
        let b_after = s
            .injections
            .iter()
            .filter(|i| i.at_tick >= down_hi)
            .filter(|i| b.contains(&i.lp))
            .count();
        assert!(b_after > 0, "B never rejoined");
    }

    #[test]
    fn kind_round_trips_through_strings() {
        for kind in ScenarioKind::ALL {
            let parsed: ScenarioKind = kind.name().parse().unwrap();
            assert_eq!(parsed, kind);
        }
        assert!("bogus".parse::<ScenarioKind>().is_err());
    }
}

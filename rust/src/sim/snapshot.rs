//! Versioned, deterministic, std-only snapshots of the full engine +
//! game state (DESIGN.md §10) — the substrate of checkpoint/restore and
//! elastic cluster membership.
//!
//! A snapshot is everything needed to resume a `sim::dynamic` run
//! bit-identically: the weighted LP graph, the machine fleet, the
//! LP-to-machine assignment, every LP's pending/processed state, GVT,
//! cumulative and windowed counters, the undelivered injection schedule,
//! estimator state, driver counters, and any RNG streams (a
//! [`Pcg32`](crate::util::rng::Pcg32) is plain `(state, inc)` data).
//!
//! # Determinism rules
//!
//! The byte encoding is canonical: encoding the same logical state
//! always yields the same bytes, and `save → load → save` is
//! byte-identical. Three rules make that hold:
//!
//! 1. **No index layout is serialized.** The engine's slot slab, lazy
//!    heaps, and active worklist are re-derived on restore; capture
//!    sorts per-LP pending events into the canonical
//!    `(time, kind-rank, thread, count, ready_at)` order and `seen`
//!    sets ascending.
//! 2. **Fixed field order, little-endian, no padding.** Every integer
//!    is a LE `u64`/`u32`/`u8`; every `f64` is its IEEE-754 bit pattern
//!    (`to_bits`), so values round-trip exactly.
//! 3. **Observational state is excluded.** Load traces restart empty on
//!    restore; they never feed back into simulation or game decisions.
//!
//! The engine-side capture/restore hooks live in
//! [`SimEngine::capture_state`](crate::sim::engine::SimEngine::capture_state)
//! and
//! [`SimEngine::from_state`](crate::sim::engine::SimEngine::from_state);
//! `DynamicDriver` assembles full [`Snapshot`]s at every epoch boundary
//! and restores from them on worker death (DESIGN.md §10).

use std::fmt;
use std::path::Path;

use crate::graph::{Graph, GraphBuilder, NodeId};
use crate::partition::MachineConfig;
use crate::sim::engine::{EpochCounters, Injection, SimOptions, SimStats};
use crate::sim::event::{Event, EventKind, SimTime, ThreadId, WallTime};

/// Snapshot file magic: "GTSN".
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"GTSN";
/// Current snapshot format version.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Decode/IO failure.
#[derive(Debug)]
pub enum SnapshotError {
    /// Filesystem error (message includes the path).
    Io(String),
    /// Structurally invalid bytes.
    Malformed(String),
    /// Valid magic but an unsupported format version.
    Version(u32),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Io(m) => write!(f, "snapshot io error: {m}"),
            SnapshotError::Malformed(m) => write!(f, "malformed snapshot: {m}"),
            SnapshotError::Version(v) => {
                write!(f, "unsupported snapshot version {v} (expected {SNAPSHOT_VERSION})")
            }
        }
    }
}

impl std::error::Error for SnapshotError {}

/// Canonical sort key for a pending event: total order over everything
/// the simulation can observe, so serialization never depends on slab
/// or heap layout. `ready_at` last: byte-identical duplicates that
/// differ only in arrival tick stay distinguishable.
pub(crate) fn pending_sort_key(
    e: &Event,
    ready_at: WallTime,
) -> (SimTime, u8, ThreadId, u32, WallTime) {
    (e.time, e.kind.rank(), e.thread, e.count, ready_at)
}

/// Captured state of one LP (canonical order; see module docs).
#[derive(Debug, Clone)]
pub struct LpState {
    /// Pending events with absolute ready ticks, canonically sorted.
    pub pending: Vec<(Event, WallTime)>,
    /// Threads seen (pending or processed), ascending. Not derivable
    /// from the rest: it outlives fossil-collected history.
    pub seen: Vec<ThreadId>,
    pub local_time: SimTime,
    /// Busy event and its absolute completion tick.
    pub busy: Option<(Event, WallTime)>,
    /// Processed-event history in retirement order, each with the
    /// neighbors it forwarded to.
    pub history: Vec<(Event, Vec<NodeId>)>,
    pub rollbacks: u64,
}

/// Captured resumable state of a [`SimEngine`](crate::sim::engine::SimEngine).
#[derive(Debug, Clone)]
pub struct EngineState {
    pub stats: SimStats,
    pub gvt: SimTime,
    pub assignment: Vec<usize>,
    /// Undelivered injections in engine order (descending `at_tick`).
    pub injections: Vec<Injection>,
    pub epoch: EpochCounters,
    pub fossil_cursor: u64,
    pub lps: Vec<LpState>,
}

/// Captured weight-estimator state (the EWMA/hysteresis memory of
/// `sim::dynamic::WeightEstimator`; configuration lives in options).
#[derive(Debug, Clone)]
pub struct EstimatorState {
    pub node_state: Vec<f64>,
    pub edge_state: Vec<f64>,
    pub node_out: Vec<f64>,
    pub edge_out: Vec<f64>,
    pub primed: bool,
}

/// A complete epoch-boundary snapshot of a `sim::dynamic` run.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Engine options (needed to rebuild an engine for `--restore`).
    pub options: SimOptions,
    /// Game-side node weights (the driver's weighted LP graph).
    pub node_weights: Vec<f64>,
    /// Edges `(u, v, w)` with game-side weights, in graph edge order.
    pub edges: Vec<(NodeId, NodeId, f64)>,
    /// Normalized machine speeds (sum 1); `speeds.len()` is K.
    pub speeds: Vec<f64>,
    /// Epochs completed at capture time.
    pub epoch: u64,
    /// Driver cumulative counters.
    pub refinements: u64,
    pub transfers: u64,
    pub migration_ticks: u64,
    /// Estimator memory (absent before the first epoch primes it).
    pub estimator: Option<EstimatorState>,
    /// RNG streams as `Pcg32::state_parts()` pairs. The epoch loop
    /// itself is RNG-free (injections are precompiled), so this is
    /// empty for `DynamicDriver` snapshots; the slot exists so drivers
    /// that do carry generators snapshot them losslessly.
    pub rng_streams: Vec<(u64, u64)>,
    pub engine: EngineState,
}

// ---------------------------------------------------------------------
// Encoding primitives
// ---------------------------------------------------------------------

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_event(b: &mut Vec<u8>, e: &Event) {
    put_u64(b, e.thread);
    put_u64(b, e.time);
    put_u8(
        b,
        match e.kind {
            EventKind::ProcessForward => 0,
            EventKind::ProcessOnly => 1,
            EventKind::Rollback => 2,
        },
    );
    put_u64(b, e.tick);
    put_u32(b, e.count);
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Reader { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], SnapshotError> {
        if self.bytes.len() - self.pos < n {
            return Err(SnapshotError::Malformed(format!(
                "truncated while reading {what} at offset {}",
                self.pos
            )));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, SnapshotError> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32, SnapshotError> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes(s.try_into().expect("4 bytes")))
    }

    fn u64(&mut self, what: &str) -> Result<u64, SnapshotError> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes(s.try_into().expect("8 bytes")))
    }

    fn f64(&mut self, what: &str) -> Result<f64, SnapshotError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// Read a length prefix, sanity-checking it against the bytes that
    /// remain (each element needs at least `min_elem_bytes`), so a
    /// corrupt count cannot trigger an absurd allocation.
    fn len(&mut self, min_elem_bytes: usize, what: &str) -> Result<usize, SnapshotError> {
        let n = self.u64(what)?;
        let n = usize::try_from(n)
            .map_err(|_| SnapshotError::Malformed(format!("{what} count {n} overflows usize")))?;
        let remaining = self.bytes.len() - self.pos;
        if n.saturating_mul(min_elem_bytes.max(1)) > remaining {
            return Err(SnapshotError::Malformed(format!(
                "{what} count {n} exceeds remaining {remaining} bytes"
            )));
        }
        Ok(n)
    }

    fn event(&mut self, what: &str) -> Result<Event, SnapshotError> {
        let thread = self.u64(what)?;
        let time = self.u64(what)?;
        let kind = match self.u8(what)? {
            0 => EventKind::ProcessForward,
            1 => EventKind::ProcessOnly,
            2 => EventKind::Rollback,
            k => {
                return Err(SnapshotError::Malformed(format!("{what}: unknown event kind {k}")))
            }
        };
        let tick = self.u64(what)?;
        let count = self.u32(what)?;
        Ok(Event { thread, time, kind, tick, count })
    }

    fn done(self) -> Result<(), SnapshotError> {
        if self.pos != self.bytes.len() {
            return Err(SnapshotError::Malformed(format!(
                "{} trailing bytes after snapshot",
                self.bytes.len() - self.pos
            )));
        }
        Ok(())
    }
}

const EVENT_BYTES: usize = 8 + 8 + 1 + 8 + 4;

impl Snapshot {
    /// Serialize to the canonical byte encoding.
    pub fn encode(&self) -> Vec<u8> {
        let b = &mut Vec::new();
        b.extend_from_slice(&SNAPSHOT_MAGIC);
        put_u32(b, SNAPSHOT_VERSION);

        // Engine options.
        put_u64(b, self.options.base_process_time);
        put_u64(b, self.options.rollback_process_time);
        put_u64(b, self.options.inter_machine_delay);
        put_u64(b, self.options.intra_machine_delay);
        put_u64(b, self.options.hop_latency);
        put_u64(b, self.options.trace_every);
        put_u64(b, self.options.max_ticks);
        put_u64(b, self.options.parallelism as u64);
        put_u64(b, self.options.parallel_min_active as u64);

        // Weighted graph.
        put_u64(b, self.node_weights.len() as u64);
        for &w in &self.node_weights {
            put_f64(b, w);
        }
        put_u64(b, self.edges.len() as u64);
        for &(u, v, w) in &self.edges {
            put_u64(b, u as u64);
            put_u64(b, v as u64);
            put_f64(b, w);
        }

        // Machines.
        put_u64(b, self.speeds.len() as u64);
        for &s in &self.speeds {
            put_f64(b, s);
        }

        // Driver counters.
        put_u64(b, self.epoch);
        put_u64(b, self.refinements);
        put_u64(b, self.transfers);
        put_u64(b, self.migration_ticks);

        // Estimator memory.
        match &self.estimator {
            None => put_u8(b, 0),
            Some(est) => {
                put_u8(b, 1);
                for vs in [&est.node_state, &est.edge_state, &est.node_out, &est.edge_out] {
                    put_u64(b, vs.len() as u64);
                    for &v in vs {
                        put_f64(b, v);
                    }
                }
                put_u8(b, u8::from(est.primed));
            }
        }

        // RNG streams.
        put_u64(b, self.rng_streams.len() as u64);
        for &(state, inc) in &self.rng_streams {
            put_u64(b, state);
            put_u64(b, inc);
        }

        // Engine state.
        let e = &self.engine;
        put_u64(b, e.stats.ticks);
        put_u64(b, e.stats.events_processed);
        put_u64(b, e.stats.events_forwarded);
        put_u64(b, e.stats.cross_machine_forwards);
        put_u64(b, e.stats.rollbacks);
        put_u64(b, e.stats.antimessages_sent);
        put_u8(b, u8::from(e.stats.truncated));
        put_u64(b, e.gvt);
        put_u64(b, e.assignment.len() as u64);
        for &m in &e.assignment {
            put_u64(b, m as u64);
        }
        put_u64(b, e.injections.len() as u64);
        for inj in &e.injections {
            put_u64(b, inj.at_tick);
            put_u64(b, inj.lp as u64);
            put_event(b, &inj.event);
        }
        put_u64(b, e.epoch.ticks);
        for vs in [
            &e.epoch.events_by_lp,
            &e.epoch.rollbacks_by_lp,
            &e.epoch.cross_forwards_by_lp,
            &e.epoch.forwards_by_half_edge,
        ] {
            put_u64(b, vs.len() as u64);
            for &v in vs {
                put_u64(b, v);
            }
        }
        put_u64(b, e.fossil_cursor);
        put_u64(b, e.lps.len() as u64);
        for lp in &e.lps {
            put_u64(b, lp.pending.len() as u64);
            for (ev, ready_at) in &lp.pending {
                put_event(b, ev);
                put_u64(b, *ready_at);
            }
            put_u64(b, lp.seen.len() as u64);
            for &t in &lp.seen {
                put_u64(b, t);
            }
            put_u64(b, lp.local_time);
            match &lp.busy {
                None => put_u8(b, 0),
                Some((ev, done_at)) => {
                    put_u8(b, 1);
                    put_event(b, ev);
                    put_u64(b, *done_at);
                }
            }
            put_u64(b, lp.history.len() as u64);
            for (ev, fwd) in &lp.history {
                put_event(b, ev);
                put_u64(b, fwd.len() as u64);
                for &nb in fwd {
                    put_u64(b, nb as u64);
                }
            }
            put_u64(b, lp.rollbacks);
        }
        std::mem::take(b)
    }

    /// Decode from bytes, validating structure and cross-field
    /// consistency (assignment bounds, counter shapes, speed sanity).
    pub fn decode(bytes: &[u8]) -> Result<Snapshot, SnapshotError> {
        let mut r = Reader::new(bytes);
        let magic = r.take(4, "magic")?;
        if magic != SNAPSHOT_MAGIC {
            return Err(SnapshotError::Malformed("bad magic (not a GTSN snapshot)".into()));
        }
        let version = r.u32("version")?;
        if version != SNAPSHOT_VERSION {
            return Err(SnapshotError::Version(version));
        }

        let options = SimOptions {
            base_process_time: r.u64("base_process_time")?,
            rollback_process_time: r.u64("rollback_process_time")?,
            inter_machine_delay: r.u64("inter_machine_delay")?,
            intra_machine_delay: r.u64("intra_machine_delay")?,
            hop_latency: r.u64("hop_latency")?,
            trace_every: r.u64("trace_every")?,
            max_ticks: r.u64("max_ticks")?,
            parallelism: r.u64("parallelism")? as usize,
            parallel_min_active: r.u64("parallel_min_active")? as usize,
        };

        let n = r.len(8, "node weights")?;
        let mut node_weights = Vec::with_capacity(n);
        for _ in 0..n {
            let w = r.f64("node weight")?;
            if !w.is_finite() {
                return Err(SnapshotError::Malformed("non-finite node weight".into()));
            }
            node_weights.push(w);
        }
        let m = r.len(24, "edges")?;
        let mut edges = Vec::with_capacity(m);
        for _ in 0..m {
            let u = r.u64("edge u")? as usize;
            let v = r.u64("edge v")? as usize;
            let w = r.f64("edge weight")?;
            if u >= n || v >= n || u == v {
                return Err(SnapshotError::Malformed(format!("edge ({u}, {v}) out of range")));
            }
            if !w.is_finite() {
                return Err(SnapshotError::Malformed("non-finite edge weight".into()));
            }
            edges.push((u, v, w));
        }

        let k = r.len(8, "speeds")?;
        if k == 0 {
            return Err(SnapshotError::Malformed("zero machines".into()));
        }
        let mut speeds = Vec::with_capacity(k);
        for _ in 0..k {
            let s = r.f64("speed")?;
            if !(s.is_finite() && s > 0.0) {
                return Err(SnapshotError::Malformed(format!("invalid machine speed {s}")));
            }
            speeds.push(s);
        }
        let total: f64 = speeds.iter().sum();
        if (total - 1.0).abs() > 1e-6 {
            return Err(SnapshotError::Malformed(format!(
                "machine speeds not normalized (sum {total})"
            )));
        }

        let epoch = r.u64("epoch")?;
        let refinements = r.u64("refinements")?;
        let transfers = r.u64("transfers")?;
        let migration_ticks = r.u64("migration_ticks")?;

        let estimator = match r.u8("estimator flag")? {
            0 => None,
            1 => {
                let mut vecs: [Vec<f64>; 4] = Default::default();
                for vs in vecs.iter_mut() {
                    let len = r.len(8, "estimator vector")?;
                    vs.reserve(len);
                    for _ in 0..len {
                        vs.push(r.f64("estimator value")?);
                    }
                }
                let [node_state, edge_state, node_out, edge_out] = vecs;
                let primed = r.u8("estimator primed")? != 0;
                Some(EstimatorState { node_state, edge_state, node_out, edge_out, primed })
            }
            f => return Err(SnapshotError::Malformed(format!("bad estimator flag {f}"))),
        };

        let nrng = r.len(16, "rng streams")?;
        let mut rng_streams = Vec::with_capacity(nrng);
        for _ in 0..nrng {
            let state = r.u64("rng state")?;
            let inc = r.u64("rng inc")?;
            if inc & 1 != 1 {
                return Err(SnapshotError::Malformed("even rng stream selector".into()));
            }
            rng_streams.push((state, inc));
        }

        let stats = SimStats {
            ticks: r.u64("ticks")?,
            events_processed: r.u64("events_processed")?,
            events_forwarded: r.u64("events_forwarded")?,
            cross_machine_forwards: r.u64("cross_machine_forwards")?,
            rollbacks: r.u64("rollbacks")?,
            antimessages_sent: r.u64("antimessages_sent")?,
            truncated: r.u8("truncated")? != 0,
        };
        let gvt = r.u64("gvt")?;

        let an = r.len(8, "assignment")?;
        if an != n {
            return Err(SnapshotError::Malformed(format!("assignment len {an} != {n} nodes")));
        }
        let mut assignment = Vec::with_capacity(an);
        for _ in 0..an {
            let a = r.u64("assignment entry")? as usize;
            if a >= k {
                return Err(SnapshotError::Malformed(format!("assignment {a} >= {k} machines")));
            }
            assignment.push(a);
        }

        let ninj = r.len(16 + EVENT_BYTES, "injections")?;
        let mut injections = Vec::with_capacity(ninj);
        for _ in 0..ninj {
            let at_tick = r.u64("injection tick")?;
            let lp = r.u64("injection lp")? as usize;
            if lp >= n {
                return Err(SnapshotError::Malformed(format!("injection lp {lp} >= {n}")));
            }
            let event = r.event("injection event")?;
            injections.push(Injection { at_tick, lp, event });
        }

        let epoch_ticks = r.u64("epoch ticks")?;
        let mut epoch_vecs: [Vec<u64>; 4] = Default::default();
        for (idx, vs) in epoch_vecs.iter_mut().enumerate() {
            let len = r.len(8, "epoch counter vector")?;
            if idx < 3 && len != n {
                return Err(SnapshotError::Malformed(format!(
                    "per-LP counter len {len} != {n} nodes"
                )));
            }
            vs.reserve(len);
            for _ in 0..len {
                vs.push(r.u64("epoch counter")?);
            }
        }
        let [events_by_lp, rollbacks_by_lp, cross_forwards_by_lp, forwards_by_half_edge] =
            epoch_vecs;
        if forwards_by_half_edge.len() != 2 * m {
            return Err(SnapshotError::Malformed(format!(
                "half-edge counter len {} != {} half-edges",
                forwards_by_half_edge.len(),
                2 * m
            )));
        }
        let epoch_counters = EpochCounters {
            ticks: epoch_ticks,
            events_by_lp,
            rollbacks_by_lp,
            cross_forwards_by_lp,
            forwards_by_half_edge,
        };

        let fossil_cursor = r.u64("fossil cursor")?;
        let nlp = r.len(8 * 5, "lps")?;
        if nlp != n {
            return Err(SnapshotError::Malformed(format!("lp count {nlp} != {n} nodes")));
        }
        let mut lps = Vec::with_capacity(nlp);
        for _ in 0..nlp {
            let np = r.len(EVENT_BYTES + 8, "pending events")?;
            let mut pending = Vec::with_capacity(np);
            for _ in 0..np {
                let ev = r.event("pending event")?;
                let ready_at = r.u64("pending ready_at")?;
                pending.push((ev, ready_at));
            }
            let ns = r.len(8, "seen threads")?;
            let mut seen = Vec::with_capacity(ns);
            for _ in 0..ns {
                seen.push(r.u64("seen thread")?);
            }
            let local_time = r.u64("local_time")?;
            let busy = match r.u8("busy flag")? {
                0 => None,
                1 => {
                    let ev = r.event("busy event")?;
                    let done_at = r.u64("busy done_at")?;
                    Some((ev, done_at))
                }
                f => return Err(SnapshotError::Malformed(format!("bad busy flag {f}"))),
            };
            let nh = r.len(EVENT_BYTES + 8, "history entries")?;
            let mut history = Vec::with_capacity(nh);
            for _ in 0..nh {
                let ev = r.event("history event")?;
                let nf = r.len(8, "forwarded_to")?;
                let mut fwd = Vec::with_capacity(nf);
                for _ in 0..nf {
                    let nb = r.u64("forwarded_to node")? as usize;
                    if nb >= n {
                        return Err(SnapshotError::Malformed(format!(
                            "forwarded_to node {nb} >= {n}"
                        )));
                    }
                    fwd.push(nb);
                }
                history.push((ev, fwd));
            }
            let rollbacks = r.u64("lp rollbacks")?;
            lps.push(LpState { pending, seen, local_time, busy, history, rollbacks });
        }
        r.done()?;

        Ok(Snapshot {
            options,
            node_weights,
            edges,
            speeds,
            epoch,
            refinements,
            transfers,
            migration_ticks,
            estimator,
            rng_streams,
            engine: EngineState {
                stats,
                gvt,
                assignment,
                injections,
                epoch: epoch_counters,
                fossil_cursor,
                lps,
            },
        })
    }

    /// Write the encoded snapshot to `path`.
    pub fn write_to(&self, path: &Path) -> Result<(), SnapshotError> {
        std::fs::write(path, self.encode())
            .map_err(|e| SnapshotError::Io(format!("{}: {e}", path.display())))
    }

    /// Read and decode a snapshot from `path`.
    pub fn read_from(path: &Path) -> Result<Snapshot, SnapshotError> {
        let bytes = std::fs::read(path)
            .map_err(|e| SnapshotError::Io(format!("{}: {e}", path.display())))?;
        Snapshot::decode(&bytes)
    }

    /// Number of machines in the snapshot fleet.
    pub fn machine_count(&self) -> usize {
        self.speeds.len()
    }

    /// Sanity-check a snapshot received as an admission catch-up
    /// payload against the fixture shipped alongside it: the fleet and
    /// LP counts must match and the engine state must cover every LP
    /// with an in-range machine. A joiner runs this before acking its
    /// admission, so a skewed leader surfaces as a clean protocol
    /// error on the joiner instead of a divergent replica later.
    pub fn validate_catchup(&self, machines: usize, nodes: usize) -> Result<(), String> {
        if self.machine_count() != machines {
            return Err(format!(
                "catch-up snapshot has {} machines, the admitted fleet has {machines}",
                self.machine_count()
            ));
        }
        if self.node_weights.len() != nodes {
            return Err(format!(
                "catch-up snapshot has {} LPs, the fixture has {nodes}",
                self.node_weights.len()
            ));
        }
        if self.engine.assignment.len() != nodes || self.engine.lps.len() != nodes {
            return Err(format!(
                "catch-up snapshot engine state covers {}/{} LPs, expected {nodes}",
                self.engine.assignment.len(),
                self.engine.lps.len()
            ));
        }
        if let Some(&bad) = self.engine.assignment.iter().find(|&&a| a >= machines) {
            return Err(format!(
                "catch-up snapshot assigns an LP to machine {bad} but K={machines}"
            ));
        }
        Ok(())
    }

    /// Rebuild the weighted LP graph (identical structure + game-side
    /// weights as at capture time).
    pub fn build_graph(&self) -> Graph {
        let mut b = GraphBuilder::with_nodes(self.node_weights.len());
        for &(u, v, w) in &self.edges {
            b.add_edge(u, v, w);
        }
        for (i, &w) in self.node_weights.iter().enumerate() {
            b.set_node_weight(i, w);
        }
        b.build()
    }

    /// Rebuild the machine fleet, adopting stored speeds verbatim.
    pub fn machines(&self) -> MachineConfig {
        MachineConfig::from_normalized(self.speeds.clone())
    }

    /// Human-readable summary (`gtip snapshot --inspect`).
    pub fn summary(&self) -> String {
        let pending: usize = self.engine.lps.iter().map(|l| l.pending.len()).sum();
        let busy = self.engine.lps.iter().filter(|l| l.busy.is_some()).count();
        let history: usize = self.engine.lps.iter().map(|l| l.history.len()).sum();
        format!(
            "snapshot v{} | epoch {} | {} LPs, {} edges, {} machines\n\
             tick {} | gvt {} | {} events processed, {} rollbacks\n\
             pending events {} | busy LPs {} | history entries {} | injections left {}\n\
             driver: {} refinements, {} transfers, {} migration ticks | estimator {} | rng streams {}",
            SNAPSHOT_VERSION,
            self.epoch,
            self.node_weights.len(),
            self.edges.len(),
            self.speeds.len(),
            self.engine.stats.ticks,
            self.engine.gvt,
            self.engine.stats.events_processed,
            self.engine.stats.rollbacks,
            pending,
            busy,
            history,
            self.engine.injections.len(),
            self.refinements,
            self.transfers,
            self.migration_ticks,
            if self.estimator.is_some() { "primed" } else { "absent" },
            self.rng_streams.len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::partition::Partition;
    use crate::sim::engine::SimEngine;

    fn fixture_snapshot() -> Snapshot {
        let mut b = GraphBuilder::with_nodes(6);
        b.add_edge(0, 1, 1.5).add_edge(1, 2, 2.0).add_edge(2, 3, 0.5);
        b.add_edge(3, 4, 1.0).add_edge(4, 5, 3.0);
        let g = b.build();
        let machines = MachineConfig::homogeneous(2);
        let part = Partition::from_assignment(&g, 2, vec![0, 0, 0, 1, 1, 1]);
        let injections: Vec<Injection> = (0..4)
            .map(|t| Injection {
                at_tick: t * 2,
                lp: (t as usize) % 6,
                event: Event::injection(t + 1, t * 5, 2),
            })
            .collect();
        let mut engine = SimEngine::new(&g, machines, part, SimOptions::default(), injections);
        for _ in 0..6 {
            engine.step();
        }
        Snapshot {
            options: SimOptions::default(),
            node_weights: g.node_weights().to_vec(),
            edges: g.edges().collect(),
            speeds: vec![0.5, 0.5],
            epoch: 3,
            refinements: 7,
            transfers: 11,
            migration_ticks: 42,
            estimator: Some(EstimatorState {
                node_state: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                edge_state: vec![0.25; 5],
                node_out: vec![1.5; 6],
                edge_out: vec![0.75; 5],
                primed: true,
            }),
            rng_streams: vec![(12345, 99 | 1)],
            engine: engine.capture_state(),
        }
    }

    #[test]
    fn save_load_save_is_byte_identical() {
        let snap = fixture_snapshot();
        let bytes = snap.encode();
        let decoded = Snapshot::decode(&bytes).expect("decode");
        let bytes2 = decoded.encode();
        assert_eq!(bytes, bytes2, "save -> load -> save must be byte-identical");
        // And once more through a restored engine.
        let g = decoded.build_graph();
        let engine = SimEngine::from_state(
            &g,
            decoded.machines(),
            decoded.options.clone(),
            decoded.engine.clone(),
        );
        let recaptured = Snapshot { engine: engine.capture_state(), ..decoded.clone() };
        assert_eq!(bytes, recaptured.encode(), "capture of a restored engine must re-encode identically");
    }

    #[test]
    fn restored_engine_continues_identically() {
        let snap = fixture_snapshot();
        let bytes = snap.encode();
        let decoded = Snapshot::decode(&bytes).expect("decode");
        let g = decoded.build_graph();
        let mut restored =
            SimEngine::from_state(&g, decoded.machines(), decoded.options.clone(), decoded.engine);

        // Uninterrupted twin from the same construction path.
        let g2 = snap.build_graph();
        let mut twin = SimEngine::from_state(
            &g2,
            snap.machines(),
            snap.options.clone(),
            snap.engine.clone(),
        );
        let a = restored.run_to_completion();
        let b = twin.run_to_completion();
        assert_eq!(a, b);
        assert_eq!(restored.gvt(), twin.gvt());
    }

    #[test]
    fn decode_rejects_bad_magic_version_and_truncation() {
        let snap = fixture_snapshot();
        let bytes = snap.encode();

        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(matches!(Snapshot::decode(&bad), Err(SnapshotError::Malformed(_))));

        let mut badv = bytes.clone();
        badv[4] = 0xFF;
        assert!(matches!(Snapshot::decode(&badv), Err(SnapshotError::Version(_))));

        for cut in [3usize, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                Snapshot::decode(&bytes[..cut]).is_err(),
                "truncation at {cut} must not decode"
            );
        }

        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(matches!(Snapshot::decode(&trailing), Err(SnapshotError::Malformed(_))));
    }

    #[test]
    fn decode_rejects_inconsistent_fields() {
        let snap = fixture_snapshot();

        let mut bad_speed = snap.clone();
        bad_speed.speeds = vec![0.9, 0.9];
        assert!(Snapshot::decode(&bad_speed.encode()).is_err(), "unnormalized speeds");

        let mut bad_assign = snap.clone();
        bad_assign.engine.assignment[0] = 99;
        assert!(Snapshot::decode(&bad_assign.encode()).is_err(), "assignment out of range");

        let mut bad_rng = snap.clone();
        bad_rng.rng_streams = vec![(1, 2)];
        assert!(Snapshot::decode(&bad_rng.encode()).is_err(), "even rng inc");
    }

    #[test]
    fn graph_round_trip_preserves_structure_and_weights() {
        let snap = fixture_snapshot();
        let g = snap.build_graph();
        assert_eq!(g.node_count(), snap.node_weights.len());
        assert_eq!(g.edge_count(), snap.edges.len());
        for &(u, v, w) in &snap.edges {
            assert_eq!(g.edge_weight(u, v), Some(w));
        }
        for (i, &w) in snap.node_weights.iter().enumerate() {
            assert_eq!(g.node_weight(i), w);
        }
    }

    #[test]
    fn summary_mentions_key_fields() {
        let snap = fixture_snapshot();
        let s = snap.summary();
        assert!(s.contains("snapshot v1"));
        assert!(s.contains("epoch 3"));
        assert!(s.contains("2 machines"));
    }
}

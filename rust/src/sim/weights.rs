//! Dynamic LP-graph weight estimation (paper §6.1).
//!
//! Before each refinement the simulator measures:
//! * node weight `b_i` = current event-list length of LP `i` (its
//!   outstanding computational load), and
//! * edge weight `c_ij` = "the sum of the number of events in `i` and
//!   `j` that generate events in `j` and `i` respectively" — i.e. the
//!   pending events at `i` that will flood to `j` (forwarding budget
//!   left and `j` has not seen the thread) plus the symmetric count.
//!
//! A small floor keeps weights strictly positive so the cost functions
//! stay well-behaved on idle regions.

use crate::graph::Graph;
use crate::sim::engine::{EpochCounters, SimEngine};
use crate::sim::event::EventKind;

/// Measured weights, ready to install into a [`Graph`].
#[derive(Debug, Clone)]
pub struct MeasuredWeights {
    pub node_weights: Vec<f64>,
    /// `(u, v, c_uv)` for every graph edge (u < v).
    pub edge_weights: Vec<(usize, usize, f64)>,
}

/// Floor applied to measured node weights (an idle LP still costs a
/// little to host).
pub const NODE_WEIGHT_FLOOR: f64 = 0.25;
/// Floor applied to measured edge weights.
pub const EDGE_WEIGHT_FLOOR: f64 = 0.0;

/// Measure weights from the engine's live LP state.
pub fn measure(engine: &SimEngine) -> MeasuredWeights {
    let g = engine.graph();
    let lps = engine.lps();
    let n = g.node_count();

    let node_weights: Vec<f64> =
        (0..n).map(|i| (lps[i].queue_len() as f64).max(NODE_WEIGHT_FLOOR)).collect();

    let mut edge_weights = Vec::with_capacity(g.edge_count());
    for (u, v, _) in g.edges() {
        let mut c: f64 = 0.0;
        // Events in u that will generate events in v:
        for ev in lps[u].pending_events() {
            if ev.kind == EventKind::ProcessForward
                && ev.count > 0
                && !lps[v].has_seen(ev.thread)
            {
                c += 1.0;
            }
        }
        // ... and symmetrically.
        for ev in lps[v].pending_events() {
            if ev.kind == EventKind::ProcessForward
                && ev.count > 0
                && !lps[u].has_seen(ev.thread)
            {
                c += 1.0;
            }
        }
        edge_weights.push((u, v, c.max(EDGE_WEIGHT_FLOOR)));
    }
    MeasuredWeights { node_weights, edge_weights }
}

/// Relative weight of one rollback episode in the measured node load: a
/// rollback occupies the LP for its own busy time *and* triggers
/// anti-message traffic, so it is costlier than a plain event.
pub const ROLLBACK_LOAD_WEIGHT: f64 = 4.0;

/// Measure weights from live LP state *plus* the activity recorded over
/// the last epoch window — the closed-loop measurement used by
/// [`crate::sim::dynamic`]:
///
/// * node weight `b_i` = outstanding backlog (queue length, as in
///   [`measure`]) + events LP `i` processed during the window +
///   [`ROLLBACK_LOAD_WEIGHT`] × its rollback episodes;
/// * edge weight `c_ij` = pending forwarding pressure (as in
///   [`measure`]) + forwards that actually crossed `{i,j}` during the
///   window.
pub fn measure_epoch(engine: &SimEngine, epoch: &EpochCounters) -> MeasuredWeights {
    let g = engine.graph();
    let lps = engine.lps();
    let base = measure(engine);
    let node_weights: Vec<f64> = (0..base.node_weights.len())
        .map(|i| {
            let backlog = lps[i].queue_len() as f64;
            let activity = epoch.events_by_lp[i] as f64
                + ROLLBACK_LOAD_WEIGHT * epoch.rollbacks_by_lp[i] as f64;
            (backlog + activity).max(NODE_WEIGHT_FLOOR)
        })
        .collect();
    let edge_weights = base
        .edge_weights
        .iter()
        .map(|&(u, v, c)| {
            (u, v, (c + epoch.forwards_on(g, u, v) as f64).max(EDGE_WEIGHT_FLOOR))
        })
        .collect();
    MeasuredWeights { node_weights, edge_weights }
}

/// Install measured weights into a graph (the LP graph used by the
/// refinement engine).
pub fn install(graph: &mut Graph, weights: &MeasuredWeights) {
    graph.set_node_weights(&weights.node_weights);
    for &(u, v, c) in &weights.edge_weights {
        graph.set_edge_weight(u, v, c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::partition::{MachineConfig, Partition};
    use crate::sim::engine::{Injection, SimEngine, SimOptions};
    use crate::sim::event::Event;

    fn setup() -> (Graph, Vec<Injection>) {
        let mut b = GraphBuilder::with_nodes(4);
        b.add_edge(0, 1, 1.0).add_edge(1, 2, 1.0).add_edge(2, 3, 1.0);
        let g = b.build();
        let inj = vec![
            Injection { at_tick: 0, lp: 0, event: Event::injection(1, 5, 3) },
            Injection { at_tick: 0, lp: 0, event: Event::injection(2, 9, 3) },
        ];
        (g, inj)
    }

    #[test]
    fn queue_lengths_become_node_weights() {
        let (g, inj) = setup();
        let machines = MachineConfig::homogeneous(2);
        let part = Partition::from_assignment(&g, 2, vec![0, 0, 1, 1]);
        let mut e = SimEngine::new(&g, machines, part, SimOptions::default(), inj);
        e.step(); // inject both events at LP0
        let w = measure(&e);
        // LP0 has 1-2 pending (one may have started processing).
        assert!(w.node_weights[0] >= 1.0);
        // Idle LPs get the floor.
        assert_eq!(w.node_weights[3], NODE_WEIGHT_FLOOR);
    }

    #[test]
    fn forwarding_pressure_creates_edge_weight() {
        let (g, inj) = setup();
        let machines = MachineConfig::homogeneous(1);
        let part = Partition::from_assignment(&g, 1, vec![0; 4]);
        let mut e = SimEngine::new(&g, machines, part, SimOptions::default(), inj);
        e.step();
        let w = measure(&e);
        // Edge (0,1): pending forward events at 0 target unseen neighbor 1.
        let c01 = w
            .edge_weights
            .iter()
            .find(|&&(u, v, _)| (u, v) == (0, 1))
            .map(|&(_, _, c)| c)
            .unwrap();
        assert!(c01 >= 1.0, "expected forwarding pressure on (0,1): {c01}");
        // Edge (2,3): no events near it yet.
        let c23 = w
            .edge_weights
            .iter()
            .find(|&&(u, v, _)| (u, v) == (2, 3))
            .map(|&(_, _, c)| c)
            .unwrap();
        assert_eq!(c23, EDGE_WEIGHT_FLOOR);
    }

    #[test]
    fn epoch_measurement_adds_activity() {
        let (g, inj) = setup();
        let machines = MachineConfig::homogeneous(1);
        let part = Partition::from_assignment(&g, 1, vec![0; 4]);
        let mut e = SimEngine::new(&g, machines, part, SimOptions::default(), inj);
        let _ = e.run_to_completion();
        let epoch = e.take_epoch_counters();
        let w = measure_epoch(&e, &epoch);
        // Drained engine: backlog is zero everywhere, so node weights are
        // exactly the per-LP processed-event counts (floored).
        for i in 0..4 {
            let expect = (epoch.events_by_lp[i] as f64
                + super::ROLLBACK_LOAD_WEIGHT * epoch.rollbacks_by_lp[i] as f64)
                .max(NODE_WEIGHT_FLOOR);
            assert_eq!(w.node_weights[i], expect, "node {i}");
        }
        // The flood traversed the whole line, so every edge saw traffic.
        for &(u, v, c) in &w.edge_weights {
            assert!(c >= 1.0, "edge ({u},{v}) saw no measured traffic: {c}");
        }
        // A fresh (empty) window degrades to the instantaneous estimate.
        let empty = e.epoch_counters();
        let w2 = measure_epoch(&e, empty);
        let w_inst = measure(&e);
        assert_eq!(w2.node_weights, w_inst.node_weights);
    }

    #[test]
    fn install_round_trips() {
        let (mut g, inj) = setup();
        let machines = MachineConfig::homogeneous(1);
        let part = Partition::from_assignment(&g, 1, vec![0; 4]);
        let g_sim = g.clone();
        let mut e = SimEngine::new(&g_sim, machines, part, SimOptions::default(), inj);
        e.step();
        let w = measure(&e);
        install(&mut g, &w);
        for (i, &nw) in w.node_weights.iter().enumerate() {
            assert_eq!(g.node_weight(i), nw);
        }
        for &(u, v, c) in &w.edge_weights {
            assert_eq!(g.edge_weight(u, v), Some(c));
        }
    }
}

//! Limited-scope flooded packet-flow workload with moving hot spots
//! (paper §6.1).
//!
//! Packets are generated at random simulation times by randomly chosen
//! LPs and flood the network for a bounded number of hops. To make the
//! load *dynamic* — the scenario the iterative repartitioner exists for —
//! the generator concentrates bursts of packets inside "hot spots":
//! BFS balls around randomly drawn centers that relocate every
//! `hot_spot_period` wall-clock ticks, exactly the "clusters of nodes
//! that generate large amounts of traffic over a short period, whose
//! locations change regularly" of §6.1.

use crate::graph::{metrics, Graph, NodeId};
use crate::sim::engine::Injection;
use crate::sim::event::Event;
use crate::util::rng::Pcg32;

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadOptions {
    /// Total packet-flood threads injected.
    pub threads: usize,
    /// Hop budget of each flood (`event-count`).
    pub hop_limit: u32,
    /// Wall-clock horizon across which injections are spread.
    pub horizon_ticks: u64,
    /// Number of simultaneous hot spots (0 = uniform traffic).
    pub hot_spots: usize,
    /// Ticks between hot-spot relocations.
    pub hot_spot_period: u64,
    /// Radius (hops) of each hot-spot BFS ball.
    pub hot_spot_radius: usize,
    /// Fraction of threads drawn from hot spots (rest uniform).
    pub hot_fraction: f64,
    /// Spread of simulation timestamps: ts uniform in
    /// `[at_tick · ts_rate, at_tick · ts_rate + ts_jitter]`, keeping
    /// virtual time loosely coupled to wall time.
    pub ts_rate: f64,
    pub ts_jitter: u64,
}

impl Default for WorkloadOptions {
    fn default() -> Self {
        WorkloadOptions {
            threads: 60,
            hop_limit: 4,
            horizon_ticks: 2_000,
            hot_spots: 3,
            hot_spot_period: 400,
            hot_spot_radius: 2,
            hot_fraction: 0.8,
            ts_rate: 0.5,
            ts_jitter: 8,
        }
    }
}

/// Generated workload: the injection schedule plus the hot-spot timeline
/// (kept for analysis / plotting).
#[derive(Debug, Clone)]
pub struct FloodWorkload {
    pub injections: Vec<Injection>,
    /// For each relocation epoch: the hot-spot member sets.
    pub hot_spot_epochs: Vec<Vec<Vec<NodeId>>>,
}

/// Nodes within `radius` hops of `center`.
fn bfs_ball(g: &Graph, center: NodeId, radius: usize) -> Vec<NodeId> {
    let d = metrics::bfs_distances(g, center);
    (0..g.node_count()).filter(|&u| d[u] <= radius).collect()
}

impl FloodWorkload {
    /// Generate a schedule over the given graph.
    pub fn generate(g: &Graph, options: &WorkloadOptions, rng: &mut Pcg32) -> FloodWorkload {
        let n = g.node_count();
        assert!(n > 0 && options.threads > 0);
        let epochs = if options.hot_spots == 0 {
            1
        } else {
            (options.horizon_ticks / options.hot_spot_period.max(1)).max(1) as usize
        };
        // Draw hot-spot balls per epoch.
        let mut hot_spot_epochs: Vec<Vec<Vec<NodeId>>> = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            let spots: Vec<Vec<NodeId>> = (0..options.hot_spots)
                .map(|_| bfs_ball(g, rng.index(n), options.hot_spot_radius))
                .collect();
            hot_spot_epochs.push(spots);
        }

        let mut injections = Vec::with_capacity(options.threads);
        for thread in 0..options.threads {
            let at_tick = rng.gen_range(0, options.horizon_ticks.saturating_sub(1).max(1));
            let epoch = if options.hot_spots == 0 {
                0
            } else {
                ((at_tick / options.hot_spot_period.max(1)) as usize).min(epochs - 1)
            };
            let lp = if options.hot_spots > 0 && rng.chance(options.hot_fraction) {
                let spots = &hot_spot_epochs[epoch];
                let spot = &spots[rng.index(spots.len())];
                spot[rng.index(spot.len())]
            } else {
                rng.index(n)
            };
            let ts_base = (at_tick as f64 * options.ts_rate) as u64;
            // jitter in [0, ts_jitter) — gen_range is inclusive.
            let ts = ts_base + rng.gen_range(0, options.ts_jitter.max(1) - 1);
            injections.push(Injection {
                at_tick,
                lp,
                event: Event::injection(thread as u64 + 1, ts, options.hop_limit),
            });
        }
        FloodWorkload { injections, hot_spot_epochs }
    }

    /// Number of injections.
    pub fn len(&self) -> usize {
        self.injections.len()
    }

    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::preferential_attachment;

    fn graph() -> Graph {
        let mut rng = Pcg32::new(1);
        preferential_attachment(150, 2, &mut rng)
    }

    #[test]
    fn generates_requested_threads_with_unique_ids() {
        let g = graph();
        let mut rng = Pcg32::new(2);
        let w = FloodWorkload::generate(&g, &WorkloadOptions::default(), &mut rng);
        assert_eq!(w.len(), 60);
        let mut ids: Vec<u64> = w.injections.iter().map(|i| i.event.thread).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 60, "thread ids must be unique");
    }

    #[test]
    fn injections_within_horizon_and_graph() {
        let g = graph();
        let mut rng = Pcg32::new(3);
        let opts = WorkloadOptions { horizon_ticks: 500, ..Default::default() };
        let w = FloodWorkload::generate(&g, &opts, &mut rng);
        for inj in &w.injections {
            assert!(inj.at_tick < 500);
            assert!(inj.lp < g.node_count());
            assert_eq!(inj.event.count, opts.hop_limit);
        }
    }

    #[test]
    fn hot_spots_concentrate_traffic() {
        let g = graph();
        let mut rng = Pcg32::new(4);
        let opts = WorkloadOptions {
            threads: 400,
            hot_spots: 2,
            hot_fraction: 0.9,
            ..Default::default()
        };
        let w = FloodWorkload::generate(&g, &opts, &mut rng);
        // Count how many injections land inside *some* epoch's hot spots.
        let mut inside = 0;
        for inj in &w.injections {
            let epoch = ((inj.at_tick / opts.hot_spot_period) as usize)
                .min(w.hot_spot_epochs.len() - 1);
            if w.hot_spot_epochs[epoch].iter().any(|s| s.contains(&inj.lp)) {
                inside += 1;
            }
        }
        let frac = inside as f64 / w.len() as f64;
        assert!(frac > 0.7, "hot fraction too low: {frac}");
    }

    #[test]
    fn hot_spots_relocate_across_epochs() {
        let g = graph();
        let mut rng = Pcg32::new(5);
        let opts = WorkloadOptions {
            horizon_ticks: 2000,
            hot_spot_period: 400,
            ..Default::default()
        };
        let w = FloodWorkload::generate(&g, &opts, &mut rng);
        assert!(w.hot_spot_epochs.len() >= 4);
        // At least one pair of consecutive epochs differs.
        let mut any_differ = false;
        for pair in w.hot_spot_epochs.windows(2) {
            if pair[0] != pair[1] {
                any_differ = true;
            }
        }
        assert!(any_differ, "hot spots never moved");
    }

    #[test]
    fn uniform_mode_has_no_hot_spots() {
        let g = graph();
        let mut rng = Pcg32::new(6);
        let opts = WorkloadOptions { hot_spots: 0, ..Default::default() };
        let w = FloodWorkload::generate(&g, &opts, &mut rng);
        assert_eq!(w.hot_spot_epochs.len(), 1);
        assert_eq!(w.len(), opts.threads);
    }

    #[test]
    fn timestamps_track_wall_clock() {
        let g = graph();
        let mut rng = Pcg32::new(7);
        let opts = WorkloadOptions { ts_rate: 0.5, ts_jitter: 4, ..Default::default() };
        let w = FloodWorkload::generate(&g, &opts, &mut rng);
        for inj in &w.injections {
            let base = (inj.at_tick as f64 * 0.5) as u64;
            assert!(inj.event.time >= base && inj.event.time < base + 4);
        }
    }
}

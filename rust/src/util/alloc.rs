//! A counting global allocator for allocation-regression tests.
//!
//! The engine's hot path is designed to be allocation-free once its
//! arenas, heaps, and scratch buffers have grown to steady-state
//! capacity (DESIGN.md §11). That claim is only enforceable if a test
//! can *observe* heap traffic, so this module wraps [`System`] with
//! per-thread allocation/deallocation counters. std-only: no jemalloc
//! shims, no external crates.
//!
//! Usage (in an integration test binary, where the global allocator
//! can be chosen without affecting the library):
//!
//! ```text
//! #[global_allocator]
//! static ALLOC: CountingAllocator = CountingAllocator;
//! ...
//! let before = alloc_count();
//! hot_loop();
//! assert_eq!(alloc_count() - before, 0);
//! ```
//!
//! Counters are thread-local, so a test measures only its own thread's
//! traffic — the parallel phase spawns scoped workers whose allocations
//! land on their own counters, which is exactly right for asserting the
//! *sequential* tick loop is allocation-free.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static DEALLOCS: Cell<u64> = const { Cell::new(0) };
    static ALLOC_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Heap allocations performed by the current thread since it started
/// (monotone; includes reallocations that obtained new memory).
pub fn alloc_count() -> u64 {
    ALLOCS.with(Cell::get)
}

/// Heap deallocations performed by the current thread.
pub fn dealloc_count() -> u64 {
    DEALLOCS.with(Cell::get)
}

/// Total bytes requested by the current thread's allocations.
pub fn alloc_bytes() -> u64 {
    ALLOC_BYTES.with(Cell::get)
}

/// A `#[global_allocator]` that delegates to [`System`] and counts
/// every allocation on thread-local counters. Zero overhead beyond two
/// thread-local increments per call; safe to install in any test
/// binary.
pub struct CountingAllocator;

// SAFETY: delegates every operation verbatim to `System`, which
// upholds the GlobalAlloc contract; the counter updates touch only
// plain thread-local `Cell<u64>`s and cannot allocate or unwind.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            ALLOCS.with(|c| c.set(c.get() + 1));
            ALLOC_BYTES.with(|c| c.set(c.get() + layout.size() as u64));
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        DEALLOCS.with(|c| c.set(c.get() + 1));
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            ALLOCS.with(|c| c.set(c.get() + 1));
            ALLOC_BYTES.with(|c| c.set(c.get() + layout.size() as u64));
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            // A grow/shrink that returns memory counts as one
            // allocation event: the hot path must not realloc either.
            ALLOCS.with(|c| c.set(c.get() + 1));
            ALLOC_BYTES.with(|c| c.set(c.get() + new_size as u64));
        }
        p
    }
}

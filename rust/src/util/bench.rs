//! Mini-criterion: a measurement harness for `cargo bench` targets.
//!
//! The offline vendor set has no `criterion`, so GTIP's benches are
//! `harness = false` binaries built on this module. It reproduces the
//! parts of criterion we rely on: warmup, adaptive iteration counts,
//! outlier-robust summaries, throughput reporting, and stable text output
//! that EXPERIMENTS.md quotes directly.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Configuration for a benchmark group.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Wall-clock budget spent warming up each benchmark.
    pub warmup: Duration,
    /// Wall-clock budget for measurement.
    pub measure: Duration,
    /// Number of sample batches the measurement budget is divided into.
    pub samples: usize,
    /// Hard cap on total iterations (guards very slow benches).
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            samples: 20,
            max_iters: u64::MAX,
        }
    }
}

impl BenchConfig {
    /// A faster profile for end-to-end benches where one iteration is
    /// already hundreds of milliseconds.
    pub fn coarse() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(1),
            samples: 3,
            max_iters: 3,
        }
    }
}

/// Result of measuring one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration time statistics (seconds).
    pub per_iter: Summary,
    pub total_iters: u64,
    /// Optional elements-per-iteration for throughput reporting.
    pub throughput_elems: Option<u64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.per_iter.mean * 1e9
    }

    fn fmt_time(secs: f64) -> String {
        if secs < 1e-6 {
            format!("{:8.2} ns", secs * 1e9)
        } else if secs < 1e-3 {
            format!("{:8.2} µs", secs * 1e6)
        } else if secs < 1.0 {
            format!("{:8.2} ms", secs * 1e3)
        } else {
            format!("{secs:8.3} s ")
        }
    }

    /// One-line report, criterion-style.
    pub fn report_line(&self) -> String {
        let mut line = format!(
            "{:<48} time: [{} {} {}]  iters: {}",
            self.name,
            Self::fmt_time(self.per_iter.p05),
            Self::fmt_time(self.per_iter.mean),
            Self::fmt_time(self.per_iter.p95),
            self.total_iters,
        );
        if let Some(elems) = self.throughput_elems {
            let eps = elems as f64 / self.per_iter.mean;
            line.push_str(&format!("  thrpt: {:.3e} elem/s", eps));
        }
        line
    }
}

/// A benchmark group: owns config and collects results.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
    group: String,
}

impl Bencher {
    pub fn new(group: impl Into<String>) -> Self {
        let group = group.into();
        let mut config = BenchConfig::default();
        // Environment knobs so `make bench` can run quick or thorough.
        if let Ok(v) = std::env::var("GTIP_BENCH_MEASURE_MS") {
            if let Ok(ms) = v.parse::<u64>() {
                config.measure = Duration::from_millis(ms);
            }
        }
        if let Ok(v) = std::env::var("GTIP_BENCH_WARMUP_MS") {
            if let Ok(ms) = v.parse::<u64>() {
                config.warmup = Duration::from_millis(ms);
            }
        }
        println!("== bench group: {group} ==");
        Bencher { config, results: Vec::new(), group }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Measure `f`, which performs ONE logical iteration per call and
    /// returns a value that is black-boxed to keep the optimizer honest.
    pub fn bench<T>(&mut self, name: impl Into<String>, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_throughput(name, None, move || {
            black_box(f());
        })
    }

    /// Like [`bench`] but records elements/iteration for throughput.
    pub fn bench_elems<T>(
        &mut self,
        name: impl Into<String>,
        elems: u64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_throughput(name, Some(elems), move || {
            black_box(f());
        })
    }

    fn bench_with_throughput(
        &mut self,
        name: impl Into<String>,
        throughput_elems: Option<u64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        let name = name.into();
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        loop {
            f();
            warm_iters += 1;
            if warm_start.elapsed() >= self.config.warmup || warm_iters >= 1_000_000 {
                break;
            }
        }
        let est_per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // Choose iterations per sample so that samples fill the budget.
        let budget = self.config.measure.as_secs_f64().max(est_per_iter);
        let total_target =
            ((budget / est_per_iter).ceil() as u64).clamp(self.config.samples as u64, self.config.max_iters);
        let iters_per_sample = (total_target / self.config.samples as u64).max(1);

        let mut sample_times = Vec::with_capacity(self.config.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            let dt = t0.elapsed().as_secs_f64();
            sample_times.push(dt / iters_per_sample as f64);
            total_iters += iters_per_sample;
            if total_iters >= self.config.max_iters {
                break;
            }
        }

        let per_iter = Summary::of(&sample_times).expect("no samples");
        let result = BenchResult { name, per_iter, total_iters, throughput_elems };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().expect("just pushed")
    }

    /// All results measured so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write results as CSV to `results/bench_<group>.csv`.
    pub fn write_csv(&self) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("results")?;
        let path = std::path::PathBuf::from(format!("results/bench_{}.csv", self.group));
        let mut out = String::from("name,mean_s,p05_s,p95_s,std_s,iters,elems_per_iter\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                r.name,
                r.per_iter.mean,
                r.per_iter.p05,
                r.per_iter.p95,
                r.per_iter.std_dev,
                r.total_iters,
                r.throughput_elems.map(|e| e.to_string()).unwrap_or_default()
            ));
        }
        std::fs::write(&path, out)?;
        println!("(wrote {})", path.display());
        Ok(path)
    }
}

/// Optimizer barrier, same contract as `std::hint::black_box` (which is
/// stable since 1.66 — we wrap it so benches read like criterion code).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Minimal JSON value for the machine-readable bench reports
/// (`results/BENCH_sim.json` & co) and the fuzz corpus
/// (`results/fuzz_corpus/*.json`). No serde offline, so this is the
/// whole document model: numbers, strings, bools, null, arrays,
/// objects — with [`JsonVal::render`] as the serializer and
/// [`parse_json`] as its parsing counterpart.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonVal {
    Null,
    Num(f64),
    Int(u64),
    Str(String),
    Bool(bool),
    Arr(Vec<JsonVal>),
    Obj(Vec<(String, JsonVal)>),
}

fn escape_json(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

impl JsonVal {
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            JsonVal::Null => out.push_str("null"),
            JsonVal::Num(x) => {
                if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            JsonVal::Int(x) => out.push_str(&format!("{x}")),
            JsonVal::Str(s) => {
                out.push('"');
                escape_json(s, out);
                out.push('"');
            }
            JsonVal::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonVal::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            JsonVal::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    escape_json(k, out);
                    out.push_str("\":");
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// A copy with every object's keys recursively sorted — the
    /// canonical form [`write_json_group`] persists so report files
    /// diff cleanly across runs regardless of construction order.
    pub fn sorted(&self) -> JsonVal {
        match self {
            JsonVal::Arr(xs) => JsonVal::Arr(xs.iter().map(JsonVal::sorted).collect()),
            JsonVal::Obj(kvs) => {
                let mut kvs: Vec<(String, JsonVal)> =
                    kvs.iter().map(|(k, v)| (k.clone(), v.sorted())).collect();
                kvs.sort_by(|a, b| a.0.cmp(&b.0));
                JsonVal::Obj(kvs)
            }
            other => other.clone(),
        }
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonVal> {
        match self {
            JsonVal::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonVal::Int(x) => Some(*x),
            JsonVal::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonVal::Num(x) => Some(*x),
            JsonVal::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonVal::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonVal::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonVal]> {
        match self {
            JsonVal::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, JsonVal::Null)
    }
}

/// Parse a JSON document into a [`JsonVal`] — the counterpart of
/// [`JsonVal::render`], used to load the fuzz corpus and bench
/// reports. Number tokens that are plain non-negative integers parse
/// as `Int` (so `u64` seeds round-trip exactly); everything else
/// numeric parses as `Num`.
pub fn parse_json(text: &str) -> Result<JsonVal, String> {
    let mut p = JsonParser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing characters at byte {}", p.pos));
    }
    Ok(v)
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: JsonVal) -> Result<JsonVal, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonVal, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonVal::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonVal::Bool(true)),
            Some(b'f') => self.literal("false", JsonVal::Bool(false)),
            Some(b'n') => self.literal("null", JsonVal::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<JsonVal, String> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonVal::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kvs.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonVal::Obj(kvs));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<JsonVal, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonVal::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonVal::Arr(xs));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err("unterminated escape".into());
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                0x10000 + ((hi - 0xD800) << 10) + (lo.wrapping_sub(0xDC00))
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| format!("bad \\u escape {code:#x}"))?,
                            );
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Decode exactly this UTF-8 sequence (the lead byte
                    // `c` was already consumed), not the whole tail.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(format!("invalid UTF-8 lead byte at {start}")),
                    };
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err("truncated UTF-8 sequence".into());
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|e| e.to_string())?;
                    out.push(s.chars().next().expect("non-empty sequence"));
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|e| e.to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|e| e.to_string())?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<JsonVal, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        if let Ok(i) = s.parse::<u64>() {
            return Ok(JsonVal::Int(i));
        }
        s.parse::<f64>()
            .map(JsonVal::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

/// Merge one named group into a line-oriented JSON report file so
/// independent bench binaries can contribute to a single document
/// (e.g. `bench_simulator` and `bench_dynamic` both filling
/// `results/BENCH_sim.json`). Controlled format — `{`, one
/// `"group": {...}` per line, `}` — rewritten wholesale on every call;
/// an existing entry for `group` is replaced. The output is
/// **deterministic**: groups are sorted by name and every object's
/// keys are sorted on write (see [`JsonVal::sorted`]), so the file
/// diffs cleanly no matter which binary wrote last or how the value
/// was assembled.
pub fn write_json_group(
    path: impl AsRef<std::path::Path>,
    group: &str,
    value: &JsonVal,
) -> std::io::Result<std::path::PathBuf> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    // Existing groups, minus the one being replaced.
    let mut entries: Vec<(String, String)> = Vec::new();
    if let Ok(existing) = std::fs::read_to_string(path) {
        for line in existing.lines() {
            let line = line.trim().trim_end_matches(',');
            if line.is_empty() || line == "{" || line == "}" {
                continue;
            }
            // `"name": {...}` — name ends at the closing quote.
            let Some(rest) = line.strip_prefix('"') else { continue };
            let Some(q) = rest.find('"') else { continue };
            let name = rest[..q].to_string();
            if name != group {
                entries.push((name, line.to_string()));
            }
        }
    }
    let mut new_line = String::from("\"");
    escape_json(group, &mut new_line);
    new_line.push_str("\": ");
    value.sorted().render_into(&mut new_line);
    entries.push((group.to_string(), new_line));
    entries.sort_by(|a, b| a.0.cmp(&b.0));

    let mut out = String::from("{\n");
    for (i, (_, line)) in entries.iter().enumerate() {
        out.push_str(line);
        if i + 1 < entries.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("}\n");
    std::fs::write(path, out)?;
    Ok(path.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            samples: 5,
            max_iters: u64::MAX,
        };
        let mut b = Bencher::new("selftest").with_config(cfg);
        let r = b.bench("sum_1k", || (0..1000u64).sum::<u64>());
        assert!(r.per_iter.mean > 0.0);
        assert!(r.total_iters >= 5);
    }

    #[test]
    fn coarse_config_caps_iters() {
        let mut b = Bencher::new("selftest2").with_config(BenchConfig::coarse());
        let r = b.bench("slowish", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.total_iters <= 3);
    }

    #[test]
    fn json_renders_and_escapes() {
        let v = JsonVal::Obj(vec![
            ("a".into(), JsonVal::Int(3)),
            ("b".into(), JsonVal::Num(1.5)),
            ("s".into(), JsonVal::Str("x\"y\\z".into())),
            ("nan".into(), JsonVal::Num(f64::NAN)),
            ("arr".into(), JsonVal::Arr(vec![JsonVal::Bool(true), JsonVal::Int(0)])),
        ]);
        assert_eq!(
            v.render(),
            "{\"a\":3,\"b\":1.5,\"s\":\"x\\\"y\\\\z\",\"nan\":null,\"arr\":[true,0]}"
        );
    }

    #[test]
    fn json_group_file_merges_groups() {
        let dir = std::env::temp_dir().join(format!("gtip_bench_json_{}", std::process::id()));
        let path = dir.join("BENCH_test.json");
        let _ = std::fs::remove_file(&path);
        write_json_group(&path, "alpha", &JsonVal::Obj(vec![("x".into(), JsonVal::Int(1))]))
            .unwrap();
        write_json_group(&path, "beta", &JsonVal::Obj(vec![("y".into(), JsonVal::Int(2))]))
            .unwrap();
        // Replacing an existing group keeps the other.
        write_json_group(&path, "alpha", &JsonVal::Obj(vec![("x".into(), JsonVal::Int(9))]))
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("{\n"));
        assert!(text.trim_end().ends_with('}'));
        assert!(text.contains("\"alpha\": {\"x\":9}"), "bad merge: {text}");
        assert!(text.contains("\"beta\": {\"y\":2}"), "lost group: {text}");
        assert_eq!(text.matches("alpha").count(), 1);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn parse_json_round_trips_values() {
        let v = JsonVal::Obj(vec![
            ("seed".into(), JsonVal::Int(u64::MAX - 3)),
            ("gap".into(), JsonVal::Num(1.2345678901234567)),
            ("name".into(), JsonVal::Str("a\"b\\c\nd".into())),
            ("flag".into(), JsonVal::Bool(false)),
            ("nothing".into(), JsonVal::Null),
            (
                "xs".into(),
                JsonVal::Arr(vec![JsonVal::Int(0), JsonVal::Num(0.5), JsonVal::Bool(true)]),
            ),
        ]);
        let text = v.render();
        let back = parse_json(&text).unwrap();
        assert_eq!(back, v, "round trip drifted: {text}");
        // u64 seeds survive exactly (not through f64).
        assert_eq!(back.get("seed").and_then(JsonVal::as_u64), Some(u64::MAX - 3));
        assert_eq!(back.get("gap").and_then(JsonVal::as_f64), Some(1.2345678901234567));
        assert_eq!(back.get("name").and_then(JsonVal::as_str), Some("a\"b\\c\nd"));
        assert!(back.get("nothing").is_some_and(JsonVal::is_null));
    }

    #[test]
    fn parse_json_accepts_pretty_whitespace_and_rejects_garbage() {
        let pretty = "{\n  \"a\": [1, 2.5,\t-3.0],\n  \"b\": { \"c\": null }\n}\n";
        let v = parse_json(pretty).unwrap();
        assert_eq!(v.get("a").and_then(JsonVal::as_arr).map(|a| a.len()), Some(3));
        assert!(parse_json("{\"a\": }").is_err());
        assert!(parse_json("[1, 2] trailing").is_err());
        assert!(parse_json("").is_err());
    }

    #[test]
    fn json_group_file_is_deterministic() {
        let dir = std::env::temp_dir().join(format!("gtip_bench_det_{}", std::process::id()));
        let path = dir.join("BENCH_det.json");
        let scrambled = JsonVal::Obj(vec![
            ("zeta".into(), JsonVal::Int(1)),
            ("alpha".into(), JsonVal::Obj(vec![
                ("y".into(), JsonVal::Int(2)),
                ("x".into(), JsonVal::Int(3)),
            ])),
        ]);
        // Write order A: beta then alpha.
        let _ = std::fs::remove_file(&path);
        write_json_group(&path, "beta", &scrambled).unwrap();
        write_json_group(&path, "alpha", &JsonVal::Int(0)).unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        // Write order B: alpha then beta.
        let _ = std::fs::remove_file(&path);
        write_json_group(&path, "alpha", &JsonVal::Int(0)).unwrap();
        write_json_group(&path, "beta", &scrambled).unwrap();
        let second = std::fs::read_to_string(&path).unwrap();
        assert_eq!(first, second, "merge order leaked into the artifact");
        // Groups sorted, object keys sorted.
        let a = first.find("\"alpha\"").unwrap();
        let b = first.find("\"beta\"").unwrap();
        assert!(a < b, "groups not sorted: {first}");
        assert!(
            first.contains("{\"alpha\":{\"x\":3,\"y\":2},\"zeta\":1}"),
            "keys not sorted: {first}"
        );
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn throughput_reported() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(5),
            samples: 3,
            max_iters: u64::MAX,
        };
        let mut b = Bencher::new("selftest3").with_config(cfg);
        let r = b.bench_elems("elems", 1234, || 42u32);
        assert_eq!(r.throughput_elems, Some(1234));
        assert!(r.report_line().contains("thrpt"));
    }
}

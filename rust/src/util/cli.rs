//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports the subset GTIP needs: positional subcommands, `--flag`,
//! `--key value` / `--key=value` options with typed accessors and
//! defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand path + options + flags + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Error type for CLI parsing/lookup.
#[derive(Debug)]
pub enum CliError {
    MissingOption(String),
    InvalidValue { key: String, value: String, reason: String },
    Unexpected(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingOption(name) => write!(f, "missing required option --{name}"),
            CliError::InvalidValue { key, value, reason } => {
                write!(f, "invalid value for --{key}: {value:?} ({reason})")
            }
            CliError::Unexpected(arg) => write!(f, "unexpected argument {arg:?}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` ends option parsing; remainder is positional.
                    args.positionals.extend(iter);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().expect("peeked");
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if a.starts_with('-') && a.len() > 1 && !a[1..2].chars().all(|c| c.is_ascii_digit()) {
                return Err(CliError::Unexpected(a));
            } else {
                args.positionals.push(a);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt_str(name).unwrap_or(default)
    }

    pub fn req_str(&self, name: &str) -> Result<&str, CliError> {
        self.opt_str(name).ok_or_else(|| CliError::MissingOption(name.to_string()))
    }

    fn parse_as<T: std::str::FromStr>(&self, name: &str, v: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        v.parse::<T>().map_err(|e| CliError::InvalidValue {
            key: name.to_string(),
            value: v.to_string(),
            reason: e.to_string(),
        })
    }

    pub fn opt<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(name) {
            None => Ok(None),
            Some(v) => Ok(Some(self.parse_as::<T>(name, v)?)),
        }
    }

    pub fn opt_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt::<T>(name)?.unwrap_or(default))
    }

    /// Comma-separated list option, e.g. `--speeds 0.1,0.2,0.3`.
    pub fn opt_list<T: std::str::FromStr>(&self, name: &str) -> Result<Option<Vec<T>>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(name) {
            None => Ok(None),
            Some(v) => {
                let mut out = Vec::new();
                for part in v.split(',').filter(|p| !p.is_empty()) {
                    out.push(self.parse_as::<T>(name, part)?);
                }
                Ok(Some(out))
            }
        }
    }

    /// First positional (typically the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positionals.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_subcommand() {
        let a = parse(&["experiment", "table1"]);
        assert_eq!(a.subcommand(), Some("experiment"));
        assert_eq!(a.positionals, vec!["experiment", "table1"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["--nodes", "230", "--mu=8.0"]);
        assert_eq!(a.opt_or::<usize>("nodes", 0).unwrap(), 230);
        assert_eq!(a.opt_or::<f64>("mu", 0.0).unwrap(), 8.0);
    }

    #[test]
    fn flags_detected() {
        let a = parse(&["run", "--verbose", "--seed", "5"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.opt_or::<u64>("seed", 0).unwrap(), 5);
    }

    #[test]
    fn list_option() {
        let a = parse(&["--speeds", "0.1,0.2,0.3,0.3,0.1"]);
        let v: Vec<f64> = a.opt_list("speeds").unwrap().unwrap();
        assert_eq!(v.len(), 5);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn negative_numbers_are_positional() {
        let a = parse(&["-5"]);
        assert_eq!(a.positionals, vec!["-5"]);
    }

    #[test]
    fn invalid_value_errors() {
        let a = parse(&["--nodes", "abc"]);
        assert!(a.opt::<usize>("nodes").is_err());
    }

    #[test]
    fn missing_required_errors() {
        let a = parse(&[]);
        assert!(matches!(a.req_str("graph"), Err(CliError::MissingOption(_))));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["--", "--not-an-option"]);
        assert_eq!(a.positionals, vec!["--not-an-option"]);
        assert!(!a.flag("not-an-option"));
    }

    #[test]
    fn unexpected_short_option_rejected() {
        let r = Args::parse(["-x".to_string()]);
        assert!(r.is_err());
    }
}

//! Shared substrates: seeded PRNG, statistics, bench harness, CLI parsing,
//! table emission and the property-testing kit.
//!
//! These exist because the offline build environment vendors no `rand`,
//! `criterion`, `clap`, or `proptest`; see DESIGN.md §3 (Substitutions).

pub mod alloc;
pub mod bench;
pub mod cli;
pub mod rng;
pub mod stats;
pub mod table;
pub mod testkit;

//! Seeded pseudo-random number generation.
//!
//! The offline vendor set ships no `rand` crate, so GTIP carries its own
//! small, well-tested generator: PCG-XSH-RR 64/32 (O'Neill 2014) seeded
//! through SplitMix64. Every experiment records its seed, making all paper
//! reproductions deterministic and re-runnable.

/// SplitMix64 — used to expand a single `u64` seed into PCG state/stream.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output with xorshift+rotate.
///
/// Small (16 bytes), fast, and statistically strong for simulation use.
/// Not cryptographic; none of GTIP needs cryptographic randomness.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64, // stream selector; always odd
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams (state and increment are both derived via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state0 = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state0.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Deterministically derive an independent stream from `(seed, tag)`
    /// **without any carrier RNG state** — unlike [`Pcg32::fork`], two
    /// calls with the same arguments always return the same stream. Used
    /// for content-addressed sub-streams (e.g. the per-gene injection
    /// streams of `sim::scenario::DriftSchedule::compile`, which must
    /// not depend on gene order or count).
    pub fn derive(seed: u64, tag: u64) -> Pcg32 {
        let mut sm = seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let state0 = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state0.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream; used to give each machine /
    /// LP / experiment arm its own generator without correlation.
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let state0 = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state0.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Raw `(state, inc)` pair for deterministic snapshotting
    /// (`sim::snapshot`). The generator is plain data; restoring via
    /// [`Pcg32::from_parts`] continues the stream bit-identically.
    #[inline]
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg32::state_parts`] pair. The
    /// increment must be odd (every constructor guarantees this, and a
    /// snapshot written by this crate always stores an odd `inc`).
    #[inline]
    pub fn from_parts(state: u64, inc: u64) -> Pcg32 {
        assert!(inc & 1 == 1, "Pcg32 stream selector must be odd");
        Pcg32 { state, inc }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform in `[0, 1)` with 32 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        f64::from(self.next_u32()) * (1.0 / 4_294_967_296.0)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    pub fn gen_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "gen_below(0)");
        let mut x = self.next_u32();
        let mut m = u64::from(x) * u64::from(bound);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = u64::from(x) * u64::from(bound);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "gen_range lo > hi");
        let span = hi - lo + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64();
        }
        if span <= u64::from(u32::MAX) {
            lo + u64::from(self.gen_below(span as u32))
        } else {
            // 64-bit Lemire
            let mut x = self.next_u64();
            let mut m = (x as u128) * (span as u128);
            let mut l = m as u64;
            if l < span {
                let t = span.wrapping_neg() % span;
                while l < t {
                    x = self.next_u64();
                    m = (x as u128) * (span as u128);
                    l = m as u64;
                }
            }
            lo + (m >> 64) as u64
        }
    }

    /// Uniform usize index in `[0, len)`. Panics on `len == 0`.
    #[inline]
    pub fn index(&mut self, len: usize) -> usize {
        self.gen_below(u32::try_from(len).expect("index len > u32::MAX")) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (polar form).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Exponential with the given rate (mean `1/rate`).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let mut u = self.next_f64();
        // avoid ln(0)
        if u <= f64::MIN_POSITIVE {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / rate
    }

    /// Poisson-distributed count (Knuth for small mean, normal approx for
    /// large mean — the simulator only needs small means).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean > 64.0 {
            let x = mean + mean.sqrt() * self.normal();
            return x.max(0.0).round() as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted choice: returns an index with probability proportional to
    /// `weights[i]`. Panics if all weights are zero/negative.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().filter(|w| **w > 0.0).sum();
        assert!(total > 0.0, "weighted_choice: no positive weight");
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if w <= 0.0 {
                continue;
            }
            if t < w {
                return i;
            }
            t -= w;
        }
        // floating-point slack: return last positive-weight index
        weights
            .iter()
            .rposition(|w| *w > 0.0)
            .expect("weighted_choice: no positive weight")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should not coincide: {same}");
    }

    #[test]
    fn derive_is_stateless_and_tag_sensitive() {
        let mut a = Pcg32::derive(7, 1);
        let mut b = Pcg32::derive(7, 1);
        for _ in 0..256 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::derive(7, 2);
        let mut d = Pcg32::derive(8, 1);
        let mut a2 = Pcg32::derive(7, 1);
        let same_tag = (0..64).filter(|_| a2.next_u32() == c.next_u32()).count();
        assert!(same_tag < 4, "tag did not matter: {same_tag}");
        let mut a3 = Pcg32::derive(7, 1);
        let same_seed = (0..64).filter(|_| a3.next_u32() == d.next_u32()).count();
        assert!(same_seed < 4, "seed did not matter: {same_seed}");
    }

    #[test]
    fn state_parts_round_trip_continues_bit_identically() {
        let mut a = Pcg32::new(99);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_parts(state, inc);
        for _ in 0..512 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    #[should_panic]
    fn from_parts_rejects_even_inc() {
        let _ = Pcg32::from_parts(0, 2);
    }

    #[test]
    fn fork_is_independent() {
        let mut a = Pcg32::new(7);
        let mut c1 = a.fork(0);
        let mut c2 = a.fork(1);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean() {
        let mut rng = Pcg32::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_below_unbiased_small() {
        let mut rng = Pcg32::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.gen_below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gen_range_inclusive_bounds_hit() {
        let mut rng = Pcg32::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = rng.gen_range(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Pcg32::new(17);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.poisson(4.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::new(19);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(23);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle left identity");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::new(29);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn weighted_choice_proportions() {
        let mut rng = Pcg32::new(31);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_choice(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    #[should_panic]
    fn weighted_choice_all_zero_panics() {
        let mut rng = Pcg32::new(37);
        rng.weighted_choice(&[0.0, 0.0]);
    }
}

//! Descriptive statistics and time-series trace recording.
//!
//! Used by the bench harness (`util::bench`), the PDES load traces
//! (Figs. 9/10) and the experiment harnesses.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        Some(Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
        })
    }

    /// Half-width of an approximate 95% confidence interval on the mean.
    pub fn ci95_half_width(&self) -> f64 {
        if self.n < 2 {
            return f64::NAN;
        }
        1.96 * self.std_dev / (self.n as f64).sqrt()
    }
}

/// Percentile (linear interpolation) of a pre-sorted slice; `p` in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Population mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (not sample variance).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Coefficient of variation (std/mean), a scale-free imbalance measure
/// used to quantify Figs. 9/10 load-balance quality.
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    (variance(xs).sqrt() / m).abs()
}

/// Online mean/variance accumulator (Welford). Constant memory; used in
/// hot loops where we cannot afford to buffer samples.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// A named time series: (t, value) pairs. Backing store for the machine
/// load traces of Figs. 9/10 and potential-descent traces.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Trace {
    pub fn new(name: impl Into<String>) -> Self {
        Trace { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn values(&self) -> Vec<f64> {
        self.points.iter().map(|(_, v)| *v).collect()
    }

    /// Down-sample to at most `max_points` by striding (keeps first/last).
    pub fn downsample(&self, max_points: usize) -> Trace {
        assert!(max_points >= 2);
        if self.points.len() <= max_points {
            return self.clone();
        }
        let stride = (self.points.len() - 1) as f64 / (max_points - 1) as f64;
        let mut out = Trace::new(self.name.clone());
        for i in 0..max_points {
            let idx = (i as f64 * stride).round() as usize;
            out.points.push(self.points[idx.min(self.points.len() - 1)]);
        }
        out
    }
}

/// Render a set of traces as a CSV string: `t,name1,name2,...` with rows
/// joined on identical t values (traces sampled on a common clock).
pub fn traces_to_csv(traces: &[Trace]) -> String {
    let mut out = String::new();
    out.push('t');
    for tr in traces {
        out.push(',');
        out.push_str(&tr.name);
    }
    out.push('\n');
    let rows = traces.iter().map(|t| t.points.len()).max().unwrap_or(0);
    for i in 0..rows {
        let t = traces
            .iter()
            .find_map(|tr| tr.points.get(i).map(|(t, _)| *t))
            .unwrap_or(i as f64);
        out.push_str(&format!("{t}"));
        for tr in traces {
            out.push(',');
            match tr.points.get(i) {
                Some((_, v)) => out.push_str(&format!("{v}")),
                None => out.push_str(""),
            }
        }
        out.push('\n');
    }
    out
}

/// Render a set of traces as a compact ASCII chart (for terminal output of
/// the figure experiments). One character column per downsampled step.
pub fn ascii_chart(traces: &[Trace], width: usize, height: usize) -> String {
    const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for tr in traces {
        for &(_, v) in &tr.points {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() || !hi.is_finite() {
        return String::from("(empty chart)\n");
    }
    if (hi - lo).abs() < f64::EPSILON {
        hi = lo + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (ti, tr) in traces.iter().enumerate() {
        let ds = tr.downsample(width.max(2));
        for (i, &(_, v)) in ds.points.iter().enumerate() {
            let col = i.min(width - 1);
            let frac = (v - lo) / (hi - lo);
            let row = ((1.0 - frac) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col] = MARKS[ti % MARKS.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{hi:>12.2} ┤\n"));
    for row in &grid {
        out.push_str("             │");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{lo:>12.2} └{}\n", "─".repeat(width)));
    out.push_str("legend: ");
    for (ti, tr) in traces.iter().enumerate() {
        out.push_str(&format!("{}={} ", MARKS[ti % MARKS.len()], tr.name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std_dev() - s.std_dev).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn cov_zero_for_constant() {
        assert!(coeff_of_variation(&[5.0, 5.0, 5.0]) < 1e-12);
        assert!(coeff_of_variation(&[1.0, 9.0]) > 0.5);
    }

    #[test]
    fn trace_downsample_keeps_endpoints() {
        let mut tr = Trace::new("x");
        for i in 0..1000 {
            tr.push(i as f64, (i * i) as f64);
        }
        let ds = tr.downsample(10);
        assert_eq!(ds.len(), 10);
        assert_eq!(ds.points[0], tr.points[0]);
        assert_eq!(ds.points[9], tr.points[999]);
    }

    #[test]
    fn traces_csv_shape() {
        let mut a = Trace::new("a");
        let mut b = Trace::new("b");
        a.push(0.0, 1.0);
        a.push(1.0, 2.0);
        b.push(0.0, 3.0);
        b.push(1.0, 4.0);
        let csv = traces_to_csv(&[a, b]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "t,a,b");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[1], "0,1,3");
    }

    #[test]
    fn ascii_chart_renders() {
        let mut tr = Trace::new("load");
        for i in 0..100 {
            tr.push(i as f64, (i as f64 / 10.0).sin());
        }
        let chart = ascii_chart(&[tr], 40, 10);
        assert!(chart.contains('*'));
        assert!(chart.contains("legend"));
    }
}

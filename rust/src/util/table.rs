//! Plain-text / Markdown / CSV table emitter for experiment reports.
//!
//! Every experiment harness prints its rows through this module so the
//! output quoted in EXPERIMENTS.md is uniformly formatted.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: build a row from display values.
    pub fn rowd(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render aligned plain text.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * w.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render GitHub-flavored Markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Render CSV (no quoting needed: emitters avoid commas in cells).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV into `results/<name>.csv`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("results")?;
        let path = std::path::PathBuf::from(format!("results/{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a float with fixed decimals, used across experiment tables.
pub fn fnum(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["trial", "C0", "iters"]);
        t.row(&["1".into(), "457134".into(), "42".into()]);
        t.row(&["2".into(), "461704".into(), "99".into()]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        assert!(txt.contains("### Demo"));
        assert!(txt.contains("457134"));
        let lines: Vec<&str> = txt.lines().collect();
        assert!(lines.len() >= 4);
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| trial | C0 | iters |"));
        assert!(md.contains("|---|---|---|"));
    }

    #[test]
    fn csv_shape() {
        let csv = sample().to_csv();
        assert_eq!(csv.lines().next().unwrap(), "trial,C0,iters");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn rowd_displays() {
        let mut t = Table::new("", &["a", "b"]);
        t.rowd(&[&1.5f64, &"x"]);
        assert_eq!(t.rows[0], vec!["1.5", "x"]);
    }
}

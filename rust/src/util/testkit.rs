//! Property-based testing kit (proptest is unavailable offline).
//!
//! A `Property` runs a check against many randomly generated cases from a
//! seeded [`Pcg32`] stream. On failure it retries with progressively
//! "smaller" generator size hints (shrink-lite) and reports the seed of
//! the failing case so it can be replayed as a deterministic unit test.

use crate::game::cost::Framework;
use crate::graph::generators::preferential_attachment;
use crate::graph::Graph;
use crate::partition::initial::grow_partition;
use crate::partition::{MachineConfig, Partition};
use crate::sim::fuzz::{self, EvalOptions, FuzzCase, Objectives};
use crate::sim::scenario::{Scenario, ScenarioKind, ScenarioOptions};
use crate::util::rng::Pcg32;

/// Generator context handed to property checks: a seeded RNG plus a size
/// hint (smaller sizes generate smaller cases).
pub struct GenCtx<'a> {
    pub rng: &'a mut Pcg32,
    pub size: usize,
}

impl<'a> GenCtx<'a> {
    /// A usize in `[lo, hi]` biased by nothing (uniform).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.gen_range(lo as u64, hi as u64) as usize
    }

    /// An f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// A vector of length in `[min_len, min(size, max_len)]` generated
    /// element-wise.
    pub fn vec_of<T>(
        &mut self,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut GenCtx) -> T,
    ) -> Vec<T> {
        let hi = max_len.min(self.size.max(min_len));
        let len = self.usize_in(min_len, hi.max(min_len));
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(f(self));
        }
        out
    }
}

/// Outcome of running a property.
#[derive(Debug)]
pub enum PropResult {
    Pass { cases: usize },
    Fail { seed: u64, case_index: usize, size: usize, message: String },
}

/// Configuration for the property runner.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, base_seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Run `check` against `config.cases` generated cases. `check` should
/// panic-free return `Err(msg)` on property violation.
pub fn run_property(
    name: &str,
    config: &PropConfig,
    mut check: impl FnMut(&mut GenCtx) -> Result<(), String>,
) -> PropResult {
    for case in 0..config.cases {
        // Ramp size so early cases are small (cheap shrink-lite ordering).
        let size = 2 + (config.max_size.saturating_sub(2)) * case / config.cases.max(1);
        let seed = config
            .base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Pcg32::new(seed);
        let mut ctx = GenCtx { rng: &mut rng, size };
        if let Err(message) = check(&mut ctx) {
            // Attempt to find a smaller failing case: re-run the same seed
            // at smaller sizes and report the smallest that still fails.
            let mut smallest = (seed, case, size, message.clone());
            for s in (2..size).rev() {
                let mut rng2 = Pcg32::new(seed);
                let mut ctx2 = GenCtx { rng: &mut rng2, size: s };
                if let Err(m2) = check(&mut ctx2) {
                    smallest = (seed, case, s, m2);
                } else {
                    break;
                }
            }
            return PropResult::Fail {
                seed: smallest.0,
                case_index: smallest.1,
                size: smallest.2,
                message: smallest.3,
            };
        }
    }
    let _ = name;
    PropResult::Pass { cases: config.cases }
}

/// Assert wrapper: panics with a replayable report on failure. This is the
/// entry point used by `#[test]` functions.
pub fn check_property(
    name: &str,
    config: PropConfig,
    check: impl FnMut(&mut GenCtx) -> Result<(), String>,
) {
    match run_property(name, &config, check) {
        PropResult::Pass { .. } => {}
        PropResult::Fail { seed, case_index, size, message } => {
            panic!(
                "property '{name}' FAILED at case {case_index} (seed={seed:#x}, size={size}):\n  {message}\n  replay: Pcg32::new({seed:#x}) with size {size}"
            );
        }
    }
}

/// Builder for the deterministic scenario fixture shared by the
/// `sim::dynamic` tests and benches: one seed pins the graph, the
/// machine pool, the App.-A initial partition, the scripted scenario
/// workload, and (on demand) a scripted weight-drift schedule — so
/// every harness compares like-for-like.
#[derive(Debug, Clone)]
pub struct ScenarioFixture {
    kind: ScenarioKind,
    seed: u64,
    nodes: usize,
    machines: usize,
    options: ScenarioOptions,
}

impl ScenarioFixture {
    pub fn new(kind: ScenarioKind, seed: u64) -> Self {
        ScenarioFixture {
            kind,
            seed,
            nodes: 150,
            machines: 4,
            options: ScenarioOptions::default(),
        }
    }

    pub fn nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes;
        self
    }

    pub fn machines(mut self, k: usize) -> Self {
        self.machines = k;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.options.threads = threads;
        self
    }

    pub fn horizon(mut self, ticks: u64) -> Self {
        self.options.horizon_ticks = ticks;
        self
    }

    pub fn scenario_options(mut self, options: ScenarioOptions) -> Self {
        self.options = options;
        self
    }

    /// Materialize the fixture. Deterministic: equal builders produce
    /// identical graphs, partitions, and injection schedules.
    pub fn build(&self) -> BuiltFixture {
        let mut rng = Pcg32::new(self.seed);
        let graph = preferential_attachment(self.nodes, 2, &mut rng);
        let machines = MachineConfig::homogeneous(self.machines);
        let scenario = Scenario::build(self.kind, &graph, &self.options, &mut rng);
        let initial = grow_partition(&graph, &machines, &mut rng);
        BuiltFixture { graph, machines, initial, scenario }
    }
}

/// A materialized [`ScenarioFixture`].
#[derive(Debug, Clone)]
pub struct BuiltFixture {
    pub graph: Graph,
    pub machines: MachineConfig,
    pub initial: Partition,
    pub scenario: Scenario,
}

impl BuiltFixture {
    /// Scripted per-epoch node-weight drift: each epoch concentrates a
    /// heavy load spike on the scenario's phase regions in rotation,
    /// over a small uniform background — the refinement-only analogue
    /// of the live measured weights.
    pub fn drift_schedule(&self, epochs: usize, rng: &mut Pcg32) -> Vec<Vec<f64>> {
        let n = self.graph.node_count();
        let regions = &self.scenario.phase_regions;
        (0..epochs)
            .map(|e| {
                let mut w: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 1.5)).collect();
                for &u in &regions[e % regions.len()] {
                    w[u] += 8.0;
                }
                w
            })
            .collect()
    }
}

/// Spawn-K-processes harness for the TCP coordinator: launches real
/// `gtip serve` worker processes for machines `1..K` of a loopback
/// cluster and kills them on drop, so integration tests can stand up a
/// genuine multi-process mesh. The caller plays machine 0 — via
/// [`crate::coordinator::net::ClusterLeader`] or by running
/// `gtip dynamic --transport tcp` itself — and passes the binary path
/// in (integration tests use `env!("CARGO_BIN_EXE_gtip")`; the library
/// cannot name the binary at compile time).
pub struct TcpClusterHarness {
    /// `host:port` per machine; index 0 is the leader's listen address.
    pub peers: Vec<String>,
    children: Vec<std::process::Child>,
}

impl TcpClusterHarness {
    /// Reserve `k` free loopback `host:port`s (bind :0, record, release;
    /// the tiny release-to-rebind window is fine for test use).
    pub fn reserve_loopback_peers(k: usize) -> Vec<String> {
        let listeners: Vec<std::net::TcpListener> = (0..k)
            .map(|_| std::net::TcpListener::bind("127.0.0.1:0").expect("bind 127.0.0.1:0"))
            .collect();
        listeners.iter().map(|l| l.local_addr().expect("local addr").to_string()).collect()
    }

    /// Spawn `gtip serve` workers for machines `1..k`. The workers dial
    /// with retry+backoff, so spawning before the leader binds is fine.
    pub fn spawn(gtip_bin: &std::path::Path, k: usize) -> std::io::Result<TcpClusterHarness> {
        Self::spawn_customized(gtip_bin, k, |_, _| {})
    }

    /// [`TcpClusterHarness::spawn`], with a per-worker hook over the
    /// command before it launches — the recovery tests use it to plant
    /// a `GTIP_SERVE_DIE` fault in one chosen worker.
    pub fn spawn_customized(
        gtip_bin: &std::path::Path,
        k: usize,
        customize: impl Fn(usize, &mut std::process::Command),
    ) -> std::io::Result<TcpClusterHarness> {
        assert!(k >= 2, "a cluster needs a leader and at least one worker");
        let peers = Self::reserve_loopback_peers(k);
        let peers_arg = peers.join(",");
        let mut children = Vec::with_capacity(k - 1);
        for machine in 1..k {
            let mut cmd = std::process::Command::new(gtip_bin);
            cmd.args(["serve", "--machine-id", &machine.to_string(), "--peers", &peers_arg])
                .stdout(std::process::Stdio::null());
            customize(machine, &mut cmd);
            children.push(cmd.spawn()?);
        }
        Ok(TcpClusterHarness { peers, children })
    }

    /// Launch a `gtip serve --join` process that asks the live cluster
    /// to re-admit `machine_id` (DESIGN.md §10). Returns the child
    /// instead of tracking it in `children`, so the harness's
    /// index↔machine bookkeeping (`join_expecting_deaths`) stays
    /// intact — the caller waits on (or kills) the joiner itself.
    pub fn spawn_joiner(
        &self,
        gtip_bin: &std::path::Path,
        machine_id: usize,
        customize: impl FnOnce(&mut std::process::Command),
    ) -> std::io::Result<std::process::Child> {
        let mut cmd = std::process::Command::new(gtip_bin);
        cmd.args([
            "serve",
            "--machine-id",
            &machine_id.to_string(),
            "--peers",
            &self.peers.join(","),
            "--join",
        ])
        .stdout(std::process::Stdio::null());
        customize(&mut cmd);
        cmd.spawn()
    }

    /// Wait for every worker to exit cleanly (they do after the
    /// leader's Goodbye); panics on a non-zero exit status.
    pub fn join(self) {
        self.join_expecting_deaths(&[]);
    }

    /// [`TcpClusterHarness::join`] for clusters where some workers
    /// were *meant* to die: machines in `killed` must exit with the
    /// `GTIP_SERVE_DIE` code 86, every survivor must exit cleanly.
    pub fn join_expecting_deaths(mut self, killed: &[usize]) {
        for (i, mut c) in self.children.drain(..).enumerate() {
            let machine = i + 1;
            let status = c.wait().expect("waiting on serve worker");
            if killed.contains(&machine) {
                assert_eq!(
                    status.code(),
                    Some(86),
                    "machine {machine} should have died via GTIP_SERVE_DIE, got {status}"
                );
            } else {
                assert!(status.success(), "surviving worker {machine} exited with {status}");
            }
        }
    }
}

impl Drop for TcpClusterHarness {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Drive a refinement ring over the surviving endpoints of a cluster
/// whose other machines died (their endpoints were dropped before the
/// round), and assert every survivor exits through the recv timeout —
/// bounded, not deadlocked. Shared by the named peer-drop regression
/// tests on both transports (`integration_coordinator.rs`).
pub fn assert_ring_unwinds_on_dead_peer<B>(
    endpoints: Vec<B>,
    graph: &Graph,
    machines: &MachineConfig,
    initial: &Partition,
    recv_timeout: std::time::Duration,
) where
    B: crate::coordinator::bus::Bus + Send + 'static,
{
    use crate::coordinator::distributed::machine_loop;
    use crate::coordinator::machine::MachineActor;
    use crate::coordinator::protocol::Message;

    assert!(!endpoints.is_empty(), "need at least one survivor");
    // Kick the ring exactly like a live run would.
    endpoints[0].send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
    let started = std::time::Instant::now();
    let graph = std::sync::Arc::new(graph.clone());
    let mut handles = Vec::new();
    for endpoint in endpoints {
        let actor = MachineActor::new(
            endpoint.id(),
            std::sync::Arc::clone(&graph),
            machines.clone(),
            initial,
            8.0,
            Framework::A,
            0.0,
        );
        handles.push(std::thread::spawn(move || {
            machine_loop(actor, &endpoint, 1e-9, 1_000_000, recv_timeout)
        }));
    }
    for h in handles {
        let outcome = h.join().expect("ring actor panicked");
        assert!(outcome.timed_out, "survivor should time out, not deadlock");
        assert!(!outcome.converged);
    }
    assert!(
        started.elapsed() < std::time::Duration::from_secs(20),
        "ring with a dead peer took {:?} to unwind",
        started.elapsed()
    );
}

/// Location of the persisted fuzz corpus, anchored at the crate root
/// so tests and benches resolve it regardless of working directory.
pub fn fuzz_corpus_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results/fuzz_corpus")
}

/// The committed fuzz corpus: every `seed-*.json` entry under
/// [`fuzz_corpus_dir`], in file-name order. The filter is applied to
/// the **file name before parsing**, so locally-found (`found-*.json`)
/// entries — even stale or malformed ones — can never change or break
/// what the regression suites replay.
pub fn committed_fuzz_corpus() -> Vec<FuzzCase> {
    let dir = fuzz_corpus_dir();
    let mut paths: Vec<std::path::PathBuf> = match std::fs::read_dir(&dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.extension().is_some_and(|x| x == "json")
                    && p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| n.starts_with("seed-"))
            })
            .collect(),
        Err(_) => return Vec::new(),
    };
    paths.sort();
    paths
        .iter()
        .map(|p| {
            FuzzCase::load(p)
                .unwrap_or_else(|e| panic!("loading committed fuzz corpus: {e}"))
        })
        .collect()
}

/// Replay one corpus case under `framework` and return the measured
/// objectives. Uses the case's stored evaluation settings (or the
/// defaults) with the differential oracle forced on. Deterministic:
/// two replays of the same case are bit-identical.
pub fn replay_fuzz_case(case: &FuzzCase, framework: Framework) -> Objectives {
    let eval = EvalOptions { framework, oracle: true, ..case.eval_options() };
    fuzz::evaluate(&case.fixture, &case.schedule, &eval)
        .unwrap_or_else(|e| panic!("replaying corpus case {:?}: {e}", case.name))
}

/// Helper: format an approximate-equality failure.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (tol {tol}, scale {scale})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_property("add_commutes", PropConfig::default(), |g| {
            let a = g.f64_in(-100.0, 100.0);
            let b = g.f64_in(-100.0, 100.0);
            assert_close(a + b, b + a, 1e-12, "a+b == b+a")
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let res = run_property(
            "always_fails_on_big",
            &PropConfig { cases: 50, base_seed: 7, max_size: 32 },
            |g| {
                let v = g.vec_of(0, 100, |g| g.usize_in(0, 10));
                if v.len() > 5 {
                    Err(format!("len {} > 5", v.len()))
                } else {
                    Ok(())
                }
            },
        );
        match res {
            PropResult::Fail { message, .. } => assert!(message.contains("> 5")),
            PropResult::Pass { .. } => panic!("should have failed"),
        }
    }

    #[test]
    fn vec_of_respects_bounds() {
        check_property("vec_len_bounds", PropConfig::default(), |g| {
            let v = g.vec_of(2, 10, |g| g.usize_in(0, 1));
            if v.len() < 2 || v.len() > 10 {
                return Err(format!("len {} out of [2,10]", v.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn scenario_fixture_is_deterministic() {
        let a = ScenarioFixture::new(ScenarioKind::HotspotShift, 42).build();
        let b = ScenarioFixture::new(ScenarioKind::HotspotShift, 42).build();
        assert_eq!(a.graph.node_count(), b.graph.node_count());
        assert_eq!(a.graph.edge_count(), b.graph.edge_count());
        assert_eq!(a.initial.assignment(), b.initial.assignment());
        assert_eq!(a.scenario.len(), b.scenario.len());
        for (x, y) in a.scenario.injections.iter().zip(&b.scenario.injections) {
            assert_eq!((x.at_tick, x.lp, x.event), (y.at_tick, y.lp, y.event));
        }
        let c = ScenarioFixture::new(ScenarioKind::HotspotShift, 43).build();
        assert_ne!(
            a.scenario.injections.iter().map(|i| i.lp).collect::<Vec<_>>(),
            c.scenario.injections.iter().map(|i| i.lp).collect::<Vec<_>>(),
            "seed must matter"
        );
    }

    #[test]
    fn drift_schedule_spikes_rotate() {
        let f = ScenarioFixture::new(ScenarioKind::DiurnalRamp, 5).nodes(100).build();
        let mut rng = Pcg32::new(9);
        let drift = f.drift_schedule(6, &mut rng);
        assert_eq!(drift.len(), 6);
        for (e, w) in drift.iter().enumerate() {
            assert_eq!(w.len(), 100);
            assert!(w.iter().all(|&x| x > 0.0));
            // Every epoch has a clear spike over the background band.
            let spiked = w.iter().filter(|&&x| x > 2.0).count();
            assert!(spiked > 0, "epoch {e}: no spiked nodes");
        }
    }

    #[test]
    fn assert_close_relative() {
        assert!(assert_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
        assert!(assert_close(1.0, 1.1, 1e-6, "small").is_err());
    }
}

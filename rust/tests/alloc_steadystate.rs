//! Steady-state allocation regression: the sequential engine tick loop
//! must perform **zero heap allocations** once its arenas, heaps, slot
//! slabs, and scratch buffers have warmed to peak capacity
//! (DESIGN.md §11). This is the enforcement half of the data-oriented
//! hot-path rewrite — without it, a stray per-event `Vec` or `HashMap`
//! rehash can silently reappear.
//!
//! Method: install a counting `#[global_allocator]` (test binaries own
//! their allocator choice; the library is untouched), drive a strictly
//! periodic flood workload — identical waves, monotone timestamps —
//! through a single-machine engine, warm up long enough for every
//! capacity to reach its periodic peak, then assert the allocation
//! counter does not move across the remaining waves.

use gtip::graph::generators::preferential_attachment;
use gtip::partition::initial::grow_partition;
use gtip::partition::MachineConfig;
use gtip::sim::engine::{Injection, SimEngine, SimOptions};
use gtip::sim::event::Event;
use gtip::util::alloc::{alloc_count, CountingAllocator};
use gtip::util::rng::Pcg32;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

const NODES: usize = 32;
/// Identical flood waves, `PERIOD` ticks apart. Each event occupies its
/// LP for `NODES × base_process_time` wall ticks on the one machine
/// (§6.1 occupancy), so a wave of `SOURCES` hop-3 floods drains well
/// inside 512 ticks.
const WAVES: u64 = 24;
const WARMUP_WAVES: u64 = 8;
const PERIOD: u64 = 512;
const SOURCES: [usize; 4] = [1, 9, 17, 25];
const HOPS: u32 = 3;

fn periodic_engine(graph: &gtip::graph::Graph) -> SimEngine<'_> {
    let machines = MachineConfig::homogeneous(1);
    let mut rng = Pcg32::new(4242);
    let initial = grow_partition(graph, &machines, &mut rng);
    let mut injections = Vec::new();
    for w in 0..WAVES {
        for (j, &lp) in SOURCES.iter().enumerate() {
            // Monotone timestamps across waves: wave w's floods can
            // never straggle behind wave w-1's processed events, so the
            // steady state is exactly periodic.
            let thread = w * SOURCES.len() as u64 + j as u64;
            let time = w * 4096 + j as u64 * 8;
            injections.push(Injection {
                at_tick: w * PERIOD,
                lp,
                event: Event::injection(thread, time, HOPS),
            });
        }
    }
    SimEngine::new(graph, machines, initial, SimOptions::default(), injections)
}

#[test]
fn sequential_tick_loop_is_allocation_free_after_warmup() {
    let mut rng = Pcg32::new(2011);
    let graph = preferential_attachment(NODES, 2, &mut rng);
    let mut engine = periodic_engine(&graph);

    // Warm up: first waves grow every buffer to its periodic peak
    // (thread-slot tables, seen bitsets, event heaps, history arenas,
    // outboxes, scratch).
    let warmup_until = WARMUP_WAVES * PERIOD;
    while engine.stats().ticks < warmup_until && engine.step() {}
    assert!(
        !engine.drained(),
        "workload drained during warmup — the steady-state segment is empty"
    );
    let events_before = engine.stats().events_processed;

    // Measure: the remaining waves (plus the final drain) must not
    // touch the heap at all.
    let allocs_before = alloc_count();
    while engine.step() {}
    let alloc_delta = alloc_count() - allocs_before;

    let stats = engine.stats();
    assert!(engine.drained(), "engine never drained: {stats:?}");
    assert!(!stats.truncated, "hit the tick cap: {stats:?}");
    let events_measured = stats.events_processed - events_before;
    assert!(
        events_measured > 100,
        "measured segment did too little work ({events_measured} events) to be meaningful"
    );
    assert_eq!(
        alloc_delta, 0,
        "steady-state tick loop allocated {alloc_delta} time(s) over {events_measured} events"
    );
}

/// The counting allocator itself counts (sanity check of the
/// instrument, not the engine).
#[test]
fn counting_allocator_observes_allocations() {
    let before = alloc_count();
    let v: Vec<u64> = Vec::with_capacity(64);
    let after = alloc_count();
    assert!(after > before, "Vec::with_capacity(64) did not register");
    drop(v);
}

//! API-surface suite for the module splits (DESIGN.md §13): every
//! path re-exported by `coordinator::net`, `sim::dynamic`,
//! `sim::engine`, and `sim::fuzz` must stay importable where it is
//! documented, and the layers must still compose — codec frames
//! round-trip, `dial_retry` establishes a framed session, a loopback
//! TCP mesh carries protocol messages and a full distributed
//! refinement, and the closed loop drives the engine end to end.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use gtip::coordinator::net::{build_tcp_bus_local, connect_mesh, decode_payload, dial_retry};
use gtip::coordinator::net::{encode_frame, parse_peers, read_frame, serve, serve_join};
use gtip::coordinator::net::{run_distributed_hierarchical_tcp_local, run_distributed_tcp_local};
use gtip::coordinator::net::{write_frame, ClusterLeader, EpochFrame, Frame, FramedConn};
use gtip::coordinator::net::{JoinRequest, NetStats, ServeSummary, SetupFrame, TcpEndpoint};
use gtip::coordinator::net::{WireError, MAX_FRAME_BYTES, WIRE_MAGIC, WIRE_VERSION};
use gtip::coordinator::{Bus, DistributedOptions, Message, OverheadStats};
use gtip::coordinator::{ClusterLeader as CoordClusterLeader, RecvOutcome};
use gtip::coordinator::{TcpEndpoint as CoordTcpEndpoint, WireError as CoordWireError};
use gtip::graph::generators::preferential_attachment;
use gtip::partition::initial::grow_partition;
use gtip::partition::MachineConfig;
use gtip::sim::dynamic::{compare_frozen_vs_rebalanced, run_closed_loop, AdmissionRecord};
use gtip::sim::dynamic::{CompareReport, DynamicDriver, DynamicOptions, DynamicReport};
use gtip::sim::dynamic::{EpochRefinement, EpochReport, EstimatorKind, RecoveryRecord};
use gtip::sim::dynamic::{RefineBackend, WeightEstimator};
use gtip::sim::engine::{EpochCounters, Injection, SimEngine, SimOptions, SimStats};
use gtip::sim::fuzz::{load_corpus, save_corpus, FuzzCase, FuzzOutcome};
use gtip::sim::fuzz::{shrink, shrink_steps, Mutator};
use gtip::sim::scenario::ScenarioKind;
use gtip::sim::{
    DynamicDriver as SimLevelDriver, FuzzCase as SimLevelFuzzCase, SimEngine as SimLevelEngine,
};
use gtip::util::rng::Pcg32;
use gtip::util::testkit::ScenarioFixture;

/// Compile-time witness that the crate-level convenience aliases
/// (`gtip::coordinator::*`, `gtip::sim::*`) are the very types the
/// split modules export — a moved or duplicated definition breaks
/// these signatures.
#[allow(dead_code)]
fn aliases_are_the_same_types<'g>(
    leader: CoordClusterLeader,
    endpoint: CoordTcpEndpoint,
    err: CoordWireError,
    driver: SimLevelDriver<'g>,
    engine: SimLevelEngine<'g>,
    case: SimLevelFuzzCase,
) -> (ClusterLeader, TcpEndpoint, WireError, DynamicDriver<'g>, SimEngine<'g>, FuzzCase) {
    (leader, endpoint, err, driver, engine, case)
}

#[test]
fn codec_constants_and_frame_roundtrip() {
    assert_eq!(&WIRE_MAGIC, b"GTIP");
    assert!(WIRE_VERSION >= 5);
    assert!(MAX_FRAME_BYTES >= 1 << 20);

    let hello = Frame::Hello { version: WIRE_VERSION, machine: 2, machines: 3 };
    let encoded = encode_frame(&hello).expect("encode");
    // The payload starts after the u32 length prefix.
    let decoded = decode_payload(&encoded[4..]).expect("decode payload");
    assert_eq!(decoded, hello);

    let mut buf = Vec::new();
    let wrote = write_frame(&mut buf, &hello).expect("write");
    assert!(wrote > 0);
    assert_eq!(read_frame(&mut &buf[..]).expect("read"), hello);

    let peers = parse_peers("a:1,b:2,c:3").expect("peers");
    assert_eq!(peers.len(), 3);
    assert!(matches!(parse_peers("only-one"), Err(WireError::Protocol(_))));
}

#[test]
fn dial_retry_establishes_a_framed_session() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let deadline = Instant::now() + Duration::from_secs(5);
    let (start, cap) = (Duration::from_millis(5), Duration::from_millis(50));
    let stream = dial_retry(deadline, start, cap, || TcpStream::connect(addr)).expect("dial");
    let conn = FramedConn::new(stream);
    conn.send(&Frame::Hello { version: WIRE_VERSION, machine: 1, machines: 2 }).expect("send");

    let (mut accepted, _) = listener.accept().expect("accept");
    match read_frame(&mut accepted).expect("inbound frame") {
        Frame::Hello { version, machine, machines } => {
            assert_eq!((version, machine, machines), (WIRE_VERSION, 1, 2));
        }
        other => panic!("expected Hello, got {other:?}"),
    }
    write_frame(&mut accepted, &Frame::Goodbye).expect("write back");
    assert_eq!(conn.recv_timeout(Duration::from_secs(5)).expect("recv"), Frame::Goodbye);
}

#[test]
fn loopback_mesh_carries_protocol_messages() {
    let (endpoints, stats) = build_tcp_bus_local(2).expect("mesh");
    let first: &TcpEndpoint = &endpoints[0];
    let _: &dyn Bus = first;
    assert_eq!(first.machine_count(), 2);

    first.send(1, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
    match endpoints[1].recv_timeout(Duration::from_secs(5)) {
        RecvOutcome::Msg(Message::TakeMyTurn { consecutive_forfeits, transfers_so_far }) => {
            assert_eq!((consecutive_forfeits, transfers_so_far), (0, 0));
        }
        other => panic!("expected TakeMyTurn, got {other:?}"),
    }
    let snapshot: OverheadStats = stats.lock().unwrap().clone();
    assert!(snapshot.total_messages() >= 1);
}

#[test]
fn distributed_refinement_over_loopback_tcp() {
    let mut rng = Pcg32::new(11);
    let graph = Arc::new(preferential_attachment(120, 2, &mut rng));
    let machines = MachineConfig::homogeneous(3);
    let initial = grow_partition(&graph, &machines, &mut rng);
    let report = run_distributed_tcp_local(
        Arc::clone(&graph),
        &machines,
        initial,
        &DistributedOptions::default(),
    )
    .expect("tcp refinement");
    assert!(report.converged);
}

#[test]
fn closed_loop_drives_the_split_engine() {
    let fixture = ScenarioFixture::new(ScenarioKind::HotspotShift, 9)
        .nodes(60)
        .machines(3)
        .threads(40)
        .horizon(400)
        .build();
    let injections: Vec<Injection> = fixture.scenario.injections.clone();

    let mut engine = SimEngine::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        SimOptions::default(),
        injections.clone(),
    );
    let stats: SimStats = engine.run_to_completion();
    assert!(stats.events_processed > 0);
    let counters: EpochCounters = engine.take_epoch_counters();
    assert_eq!(counters.events_by_lp.len(), fixture.graph.node_count());

    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
        epoch_ticks: 100,
        backend: RefineBackend::Sequential,
        ..Default::default()
    };
    let mut loop_rng = Pcg32::new(5);
    let report: DynamicReport = run_closed_loop(
        &fixture.graph,
        &fixture.machines,
        injections.clone(),
        WeightEstimator::ewma(0.5),
        &options,
        &mut loop_rng,
    );
    assert!(!report.epochs.is_empty());
    let first: &EpochReport = &report.epochs[0];
    assert!(first.tick_end >= first.tick_start);

    let cmp: CompareReport = compare_frozen_vs_rebalanced(
        &fixture.graph,
        &fixture.machines,
        &fixture.initial,
        &injections,
        WeightEstimator::ewma(0.5),
        &options,
    );
    assert!(cmp.speedup() > 0.0);
}

#[test]
fn fuzz_corpus_and_mutators_round_trip() {
    let dir = std::env::temp_dir().join(format!("gtip_api_surface_{}", std::process::id()));
    let cases: Vec<FuzzCase> = load_corpus(&dir).expect("missing dir is an empty corpus");
    assert!(cases.is_empty());

    let outcome = FuzzOutcome {
        handwritten: Vec::new(),
        handwritten_best_gap: 0.0,
        found: Vec::new(),
        evaluations: 0,
    };
    let written = save_corpus(&dir, &outcome).expect("save empty corpus");
    assert!(written.is_empty());
    std::fs::remove_dir_all(&dir).ok();

    let mutator = Mutator { nodes: 40, thread_budget: 64, epoch_pm: 100, max_genes: 6 };
    let mut rng = Pcg32::new(3);
    let schedule = mutator.random_schedule(1_000, 4, &mut rng);
    let steps = shrink_steps(&schedule);
    assert!(steps.iter().all(|s| s.genes.len() <= schedule.genes.len()));
}

#[test]
fn remaining_re_exports_stay_addressable() {
    // Function items: binding fails to compile if a path moves.
    let _ = (connect_mesh, run_distributed_hierarchical_tcp_local, serve, serve_join, shrink);
    // Role and record types reachable at their documented paths.
    let _: Option<(ClusterLeader, JoinRequest, ServeSummary, SetupFrame, EpochFrame)> = None;
    let _: Option<(DynamicDriver, EpochRefinement, EstimatorKind, AdmissionRecord)> = None;
    let _: Option<RecoveryRecord> = None;
    let net_stats = NetStats { control_messages: 0, control_bytes: 0 };
    assert_eq!(net_stats.control_bytes, 0);
}

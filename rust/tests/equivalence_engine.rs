//! Engine equivalence suite: the optimized `SimEngine` (active-LP
//! worklist, indexed event queues, incremental GVT, tick fast-forward,
//! parallel per-machine execution) must be **bit-identical** to the
//! retained naive `ReferenceEngine` — same `SimStats`, same
//! `EpochCounters`, same final GVT — across every scenario kind, at
//! every parallelism level, with and without mid-run repartitioning.

use gtip::partition::{MachineConfig, Partition};
use gtip::sim::engine::{EpochCounters, Injection, SimEngine, SimOptions, SimStats};
use gtip::sim::event::SimTime;
use gtip::sim::reference::ReferenceEngine;
use gtip::sim::scenario::ScenarioKind;
use gtip::util::rng::Pcg32;
use gtip::util::testkit::{committed_fuzz_corpus, BuiltFixture, ScenarioFixture};

/// Outcome triple the suite compares.
#[derive(Debug, PartialEq)]
struct Outcome {
    stats: SimStats,
    gvt: SimTime,
    epoch: EpochCounters,
}

fn run_reference(fixture: &BuiltFixture, options: &SimOptions) -> Outcome {
    let mut e = ReferenceEngine::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        options.clone(),
        fixture.scenario.injections.clone(),
    );
    let stats = e.run_to_completion();
    Outcome { stats, gvt: e.gvt(), epoch: e.take_epoch_counters() }
}

fn run_optimized(fixture: &BuiltFixture, options: &SimOptions) -> Outcome {
    let mut e = SimEngine::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        options.clone(),
        fixture.scenario.injections.clone(),
    );
    let stats = e.run_to_completion();
    Outcome { stats, gvt: e.gvt(), epoch: e.take_epoch_counters() }
}

fn options_with(parallelism: usize) -> SimOptions {
    SimOptions {
        max_ticks: 500_000,
        parallelism,
        // Force the parallel path even on small fixtures.
        parallel_min_active: 0,
        ..Default::default()
    }
}

/// Optimized engine == naive reference on every scenario kind, and the
/// parallel paths (2 and 4 workers) == sequential, bit for bit.
#[test]
fn optimized_matches_reference_on_all_scenarios() {
    for kind in ScenarioKind::ALL {
        for seed in [2011u64, 7] {
            let fixture = ScenarioFixture::new(kind, seed).build();
            let reference = run_reference(&fixture, &options_with(1));
            assert!(!reference.stats.truncated, "{kind:?}/{seed}: reference truncated");
            for parallelism in [1usize, 2, 4] {
                let optimized = run_optimized(&fixture, &options_with(parallelism));
                assert_eq!(
                    reference, optimized,
                    "{kind:?} seed {seed} parallelism {parallelism} diverged"
                );
            }
        }
    }
}

/// Equivalence holds with load-trace recording on (trace points gate
/// the fast-forward) — including the traces themselves.
#[test]
fn equivalence_with_traces_enabled() {
    let fixture = ScenarioFixture::new(ScenarioKind::FlashCrowd, 42).build();
    let options = SimOptions { trace_every: 37, ..options_with(2) };

    let mut reference = ReferenceEngine::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        options.clone(),
        fixture.scenario.injections.clone(),
    );
    let ref_stats = reference.run_to_completion();

    let mut optimized = SimEngine::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        options,
        fixture.scenario.injections.clone(),
    );
    let opt_stats = optimized.run_to_completion();

    assert_eq!(ref_stats, opt_stats);
    assert_eq!(reference.gvt(), optimized.gvt());
    assert_eq!(reference.load_traces().len(), optimized.load_traces().len());
    for (a, b) in reference.load_traces().iter().zip(optimized.load_traces()) {
        assert_eq!(a.len(), b.len(), "trace lengths diverged");
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.0, pb.0, "trace x diverged");
            assert!((pa.1 - pb.1).abs() < 1e-12, "trace y diverged: {} vs {}", pa.1, pb.1);
        }
    }
}

/// Equivalence under the closed loop's set_partition hook: both engines
/// get the same repartition schedule applied at the same boundaries
/// (`step_bounded` keeps the optimized engine's jumps inside them).
#[test]
fn equivalence_under_mid_run_repartitioning() {
    let fixture = ScenarioFixture::new(ScenarioKind::HotspotShift, 5).build();
    let n = fixture.graph.node_count();
    let k = fixture.machines.count();
    let period = 150u64;
    let assignments: Vec<Vec<usize>> = (0..4)
        .map(|r| (0..n).map(|i| (i + r) % k).collect())
        .collect();

    let run_ref = || {
        let mut e = ReferenceEngine::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            options_with(1),
            fixture.scenario.injections.clone(),
        );
        let mut swaps = 0usize;
        loop {
            if !e.step() {
                break;
            }
            let tick = e.stats().ticks;
            if tick % period == 0 && swaps < assignments.len() {
                e.set_partition(Partition::from_assignment(
                    &fixture.graph,
                    k,
                    assignments[swaps].clone(),
                ));
                swaps += 1;
            }
            if tick > 400_000 {
                panic!("runaway");
            }
        }
        (e.stats().clone(), e.gvt(), e.take_epoch_counters())
    };

    let run_opt = |parallelism: usize| {
        let mut e = SimEngine::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            options_with(parallelism),
            fixture.scenario.injections.clone(),
        );
        let mut swaps = 0usize;
        loop {
            let tick = e.stats().ticks;
            let boundary = (tick / period + 1) * period;
            if !e.step_bounded(boundary) {
                break;
            }
            let tick = e.stats().ticks;
            if tick % period == 0 && swaps < assignments.len() {
                e.set_partition(Partition::from_assignment(
                    &fixture.graph,
                    k,
                    assignments[swaps].clone(),
                ));
                swaps += 1;
            }
            if tick > 400_000 {
                panic!("runaway");
            }
        }
        (e.stats().clone(), e.gvt(), e.take_epoch_counters())
    };

    let reference = run_ref();
    for parallelism in [1usize, 2, 4] {
        let optimized = run_opt(parallelism);
        assert_eq!(reference.0, optimized.0, "stats diverged at parallelism {parallelism}");
        assert_eq!(reference.1, optimized.1, "gvt diverged at parallelism {parallelism}");
        assert_eq!(reference.2, optimized.2, "epoch diverged at parallelism {parallelism}");
    }
}

/// Equivalence on the prop_invariants-style randomized fixtures: random
/// graphs, machine counts, thread loads and horizons.
#[test]
fn equivalence_on_randomized_fixtures() {
    let mut rng = Pcg32::new(0xE0_15);
    for case in 0..6u64 {
        let kind = ScenarioKind::ALL[(case % 4) as usize];
        let seed = rng.next_u64();
        let fixture = ScenarioFixture::new(kind, seed)
            .nodes(40 + (case as usize) * 17)
            .machines(2 + (case as usize) % 3)
            .threads(24 + (case as usize) * 7)
            .horizon(400 + case * 130)
            .build();
        let reference = run_reference(&fixture, &options_with(1));
        for parallelism in [1usize, 3] {
            let optimized = run_optimized(&fixture, &options_with(parallelism));
            assert_eq!(
                reference, optimized,
                "case {case} ({kind:?}, seed {seed:#x}) diverged at parallelism {parallelism}"
            );
        }
    }
}

/// Corpus-driven differential case: every committed adversarial
/// schedule from the fuzz corpus (`results/fuzz_corpus/seed-*.json`)
/// keeps the optimized engine bit-identical to the naive reference —
/// `SimStats`, `EpochCounters`, and final GVT — at parallelism 1/2/4.
/// Worst-case drift found by search gets exactly the same equivalence
/// guarantee as the hand-written scenarios above.
#[test]
fn corpus_schedules_match_reference_at_every_parallelism() {
    let corpus = committed_fuzz_corpus();
    assert!(!corpus.is_empty(), "committed fuzz corpus is empty");
    for case in corpus {
        let (graph, machines, initial) = case.fixture.build();
        let injections = case.schedule.compile(&graph);

        let mut reference = ReferenceEngine::new(
            &graph,
            machines.clone(),
            initial.clone(),
            options_with(1),
            injections.clone(),
        );
        let ref_stats = reference.run_to_completion();
        assert!(!ref_stats.truncated, "{}: reference truncated", case.name);
        let expected = Outcome {
            stats: ref_stats,
            gvt: reference.gvt(),
            epoch: reference.take_epoch_counters(),
        };

        for parallelism in [1usize, 2, 4] {
            let mut engine = SimEngine::new(
                &graph,
                machines.clone(),
                initial.clone(),
                options_with(parallelism),
                injections.clone(),
            );
            let stats = engine.run_to_completion();
            let actual =
                Outcome { stats, gvt: engine.gvt(), epoch: engine.take_epoch_counters() };
            assert_eq!(
                expected, actual,
                "{} diverged from sim::reference at parallelism {parallelism}",
                case.name
            );
        }
    }
}

/// Fast-forward must not change outcomes on sparse workloads with huge
/// idle gaps (the case it optimizes hardest).
#[test]
fn equivalence_on_sparse_injection_schedules() {
    let mut rng = Pcg32::new(99);
    let graph = gtip::graph::generators::preferential_attachment(60, 2, &mut rng);
    let machines = MachineConfig::homogeneous(3);
    let part = Partition::from_assignment(&graph, 3, (0..60).map(|i| i % 3).collect());
    let injections: Vec<Injection> = (0..10u64)
        .map(|t| Injection {
            at_tick: t * 5_000,
            lp: (t as usize * 13) % 60,
            event: gtip::sim::event::Event::injection(t + 1, t * 400, 3),
        })
        .collect();
    let options = SimOptions { max_ticks: 500_000, ..Default::default() };

    let mut reference = ReferenceEngine::new(
        &graph,
        machines.clone(),
        part.clone(),
        options.clone(),
        injections.clone(),
    );
    let ref_stats = reference.run_to_completion();
    assert!(!ref_stats.truncated);

    let mut optimized = SimEngine::new(&graph, machines, part, options, injections);
    let mut steps = 0u64;
    while optimized.stats().ticks < 500_000 {
        if !optimized.step() {
            break;
        }
        steps += 1;
    }
    let mut opt_stats = optimized.stats().clone();
    if !optimized.drained() {
        opt_stats.truncated = true;
    }
    assert_eq!(ref_stats, opt_stats);
    assert_eq!(reference.gvt(), optimized.gvt());
    assert_eq!(reference.take_epoch_counters(), optimized.take_epoch_counters());
    assert!(
        steps < ref_stats.ticks / 10,
        "fast-forward barely engaged: {steps} steps for {} ticks",
        ref_stats.ticks
    );
}

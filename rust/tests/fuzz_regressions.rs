//! Fuzz-corpus regression suite: the committed worst-case drift
//! schedules (`results/fuzz_corpus/seed-*.json`) must keep replaying —
//! deterministically (same seeds ⇒ byte-identical objectives), with
//! per-epoch potential descent intact under both cost frameworks, and
//! with the optimized engine still bit-identical to `sim::reference`
//! (the differential oracle runs inside every replay).

use gtip::game::cost::Framework;
use gtip::sim::fuzz::{evaluate, FuzzCase};
use gtip::util::bench::parse_json;
use gtip::util::testkit::{committed_fuzz_corpus, fuzz_corpus_dir, replay_fuzz_case};

#[test]
fn committed_corpus_exists_and_validates() {
    let corpus = committed_fuzz_corpus();
    assert!(
        !corpus.is_empty(),
        "no seed-*.json schedules under {}",
        fuzz_corpus_dir().display()
    );
    for case in &corpus {
        assert!(case.name.starts_with("seed-"), "committed case misnamed: {}", case.name);
        let (graph, machines, initial) = case.fixture.build();
        assert_eq!(graph.node_count(), case.fixture.nodes);
        assert_eq!(machines.count(), case.fixture.machines);
        assert_eq!(initial.node_count(), graph.node_count());
        case.schedule
            .validate(graph.node_count())
            .unwrap_or_else(|e| panic!("{}: invalid schedule: {e}", case.name));
        let injections = case.schedule.compile(&graph);
        assert_eq!(injections.len() as u64, case.schedule.total_threads());
    }
}

/// Same seeds ⇒ byte-identical scores: two in-process replays under
/// the case's stored evaluation settings agree on every objective bit,
/// and any objectives stored in the corpus file match the measurement
/// exactly.
#[test]
fn corpus_replays_byte_identically() {
    for case in committed_fuzz_corpus() {
        let eval = case.eval_options();
        let a = evaluate(&case.fixture, &case.schedule, &eval).unwrap();
        let b = evaluate(&case.fixture, &case.schedule, &eval).unwrap();
        assert!(
            a.bit_eq(&b),
            "{}: non-deterministic replay:\n  {a:?}\n  {b:?}",
            case.name
        );
        if let Some(stored) = &case.objectives {
            assert!(
                a.bit_eq(stored),
                "{}: replay drifted from stored objectives:\n  stored   {stored:?}\n  measured {a:?}",
                case.name
            );
        }
        // The corpus file itself round-trips exactly through the JSON
        // layer (what `gtip fuzz --replay` depends on).
        let text = case.to_json().render();
        let back = FuzzCase::from_json(&parse_json(&text).unwrap()).unwrap();
        assert_eq!(back.schedule, case.schedule, "{}: schedule JSON drifted", case.name);
        assert_eq!(back.fixture, case.fixture);
    }
}

/// The corpus exercises the engine-*configuration* axes the fuzzer
/// searches (machine speeds, transfer delays), not just drift
/// schedules: at least one committed case must pin a heterogeneous
/// machine pool and non-default delays, and its stored configuration
/// must round-trip and rebuild deterministically.
#[test]
fn corpus_covers_non_default_engine_configurations() {
    let corpus = committed_fuzz_corpus();
    let hetero = corpus
        .iter()
        .find(|c| c.name == "seed-hetero-config")
        .expect("seed-hetero-config.json missing from the committed corpus");
    assert_ne!(hetero.fixture.speed_seed, 0, "case must pin heterogeneous speeds");
    let eval = hetero.eval_options();
    let default = gtip::sim::fuzz::EvalOptions::default();
    assert!(
        eval.inter_machine_delay != default.inter_machine_delay
            || eval.intra_machine_delay != default.intra_machine_delay,
        "case must pin non-default transfer delays"
    );
    // The heterogeneous pool derives deterministically and differs
    // from the homogeneous pool legacy fixtures build.
    let (_, machines_a, _) = hetero.fixture.build();
    let (_, machines_b, _) = hetero.fixture.build();
    assert_eq!(machines_a.speeds(), machines_b.speeds());
    let homogeneous =
        gtip::sim::fuzz::FuzzFixture { speed_seed: 0, ..hetero.fixture }.build_machines();
    assert_ne!(machines_a.speeds(), homogeneous.speeds());
    // Legacy corpus entries (no speed_seed field) stay homogeneous.
    for case in &corpus {
        if case.name != "seed-hetero-config" {
            assert_eq!(case.fixture.speed_seed, 0, "{}: unexpected speed_seed", case.name);
        }
    }
}

/// Thm 4.1 on every minimized schedule, both frameworks: no refinement
/// epoch may raise the potential, the differential oracle must agree,
/// and neither arm may hit the tick cap.
#[test]
fn corpus_descent_and_oracle_hold_both_frameworks() {
    for case in committed_fuzz_corpus() {
        for framework in [Framework::A, Framework::B] {
            let obj = replay_fuzz_case(&case, framework);
            assert_eq!(
                obj.descent_violations, 0,
                "{} ({framework}): potential descent violated: {obj:?}",
                case.name
            );
            assert!(
                !obj.oracle_divergence,
                "{} ({framework}): optimized engine diverged from sim::reference",
                case.name
            );
            assert!(
                !obj.frozen_truncated && !obj.rebalanced_truncated,
                "{} ({framework}): run truncated at the tick cap: {obj:?}",
                case.name
            );
            assert!(obj.refinements > 0, "{} ({framework}): loop never refined", case.name);
        }
    }
}

//! Integration tests of the distributed coordinator: protocol
//! correctness at scale, the measured §4.5 feasibility claim
//! (synchronization bytes per transfer independent of N), latency
//! robustness, and equivalence with the sequential engine.

use std::sync::Arc;
use std::time::Duration;

use gtip::coordinator::bus::build_bus;
use gtip::coordinator::net::{build_tcp_bus_local, run_distributed_tcp_local, ClusterLeader};
use gtip::coordinator::{run_distributed, DistributedOptions};
use gtip::game::cost::{CostModel, Framework};
use gtip::game::refine::{RefineEngine, RefineOptions};
use gtip::graph::generators::preferential_attachment;
use gtip::partition::initial::grow_partition;
use gtip::partition::{global_cost, MachineConfig, Partition};
use gtip::util::rng::Pcg32;
use gtip::util::testkit::{assert_ring_unwinds_on_dead_peer, TcpClusterHarness};

/// §4.5 measured: bytes of synchronization per transfer must be flat as
/// the simulated graph grows 8x.
#[test]
fn sync_overhead_independent_of_n() {
    let machines = MachineConfig::homogeneous(5);
    let mut bytes_per_transfer = Vec::new();
    for n in [200usize, 800, 1600] {
        let mut rng = Pcg32::new(7);
        let graph = Arc::new(preferential_attachment(n, 2, &mut rng));
        let initial = grow_partition(&graph, &machines, &mut rng);
        let report = run_distributed(
            Arc::clone(&graph),
            &machines,
            initial,
            &DistributedOptions::default(),
        );
        assert!(report.converged);
        assert!(report.transfers > 0, "n={n}: no transfers at all");
        bytes_per_transfer.push(report.overhead.bytes_per_transfer(report.transfers as u64));
    }
    let min = bytes_per_transfer.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = bytes_per_transfer.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        (max - min).abs() < 1e-9,
        "bytes/transfer varies with N: {bytes_per_transfer:?}"
    );
}

/// Distributed == sequential for several seeds and both frameworks.
#[test]
fn distributed_equals_sequential_many_seeds() {
    for seed in [1u64, 2, 3] {
        for fw in [Framework::A, Framework::B] {
            let mut rng = Pcg32::new(seed);
            let graph = Arc::new(preferential_attachment(150, 2, &mut rng));
            let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
            let assignment: Vec<usize> = (0..150).map(|_| rng.index(5)).collect();
            let initial = Partition::from_assignment(&graph, 5, assignment);

            let mut seq = RefineEngine::new(&graph, &machines, initial.clone(), 8.0, fw);
            let seq_report = seq.run(&RefineOptions::default());

            let dist = run_distributed(
                Arc::clone(&graph),
                &machines,
                initial,
                &DistributedOptions { framework: fw, ..Default::default() },
            );
            assert_eq!(
                dist.partition.assignment(),
                seq.partition().assignment(),
                "seed {seed} fw {fw}: assignments differ"
            );
            assert_eq!(dist.transfers, seq_report.transfers);
        }
    }
}

/// Determinism cross-check: with the same seed, graph, and round-robin
/// turn order, the distributed coordinator and the sequential
/// `RefineEngine` must produce *identical* final partitions AND
/// identical potentials — including across warm-started refinement
/// epochs with drifting node/edge weights (the closed `sim::dynamic`
/// loop relies on this equivalence to make its backends swappable).
#[test]
fn distributed_equals_sequential_partitions_and_potentials_under_drift() {
    for fw in [Framework::A, Framework::B] {
        let mut rng = Pcg32::new(31);
        let mut graph = preferential_attachment(120, 2, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let mut seq_part =
            Partition::from_assignment(&graph, 4, (0..120).map(|_| rng.index(4)).collect());
        let mut dist_part = seq_part.clone();

        // Three epochs of scripted weight drift, each refined from the
        // previous equilibrium by both implementations.
        for epoch in 0..3u64 {
            let weights: Vec<f64> =
                (0..120).map(|i| 1.0 + ((i as u64 * 7 + epoch * 13) % 11) as f64).collect();
            graph.set_node_weights(&weights);
            seq_part.rebuild_aggregates(&graph);
            dist_part.rebuild_aggregates(&graph);

            let mut seq = RefineEngine::new(&graph, &machines, seq_part, 8.0, fw);
            let seq_report = seq.run(&RefineOptions::default());
            let seq_potential = seq.potential();
            seq_part = seq.into_partition();

            let dist = run_distributed(
                Arc::new(graph.clone()),
                &machines,
                dist_part,
                &DistributedOptions { framework: fw, ..Default::default() },
            );
            dist_part = dist.partition;

            assert_eq!(
                seq_part.assignment(),
                dist_part.assignment(),
                "fw {fw} epoch {epoch}: assignments diverged"
            );
            assert_eq!(
                seq_report.transfers, dist.transfers,
                "fw {fw} epoch {epoch}: transfer counts diverged"
            );
            // Identical partitions must score identical potentials; also
            // pin the sequential engine's incremental potential to the
            // from-scratch evaluation.
            let (c0_seq, c0t_seq) = global_cost::both(&graph, &machines, &seq_part, 8.0);
            let (c0_dist, c0t_dist) = global_cost::both(&graph, &machines, &dist_part, 8.0);
            assert_eq!(c0_seq, c0_dist, "fw {fw} epoch {epoch}: C0 diverged");
            assert_eq!(c0t_seq, c0t_dist, "fw {fw} epoch {epoch}: C~0 diverged");
            let scratch = match fw {
                Framework::A => c0_seq,
                Framework::B => c0t_seq,
            };
            assert!(
                (seq_potential - scratch).abs() <= 1e-6 * (1.0 + scratch.abs()),
                "fw {fw} epoch {epoch}: incremental potential {seq_potential} vs scratch {scratch}"
            );
        }
    }
}

/// With injected per-message latency (remotely connected machines), the
/// protocol still converges to the same equilibrium.
#[test]
fn latency_does_not_change_result() {
    let mut rng = Pcg32::new(5);
    let graph = Arc::new(preferential_attachment(100, 2, &mut rng));
    let machines = MachineConfig::homogeneous(4);
    let assignment: Vec<usize> = (0..100).map(|_| rng.index(4)).collect();
    let initial = Partition::from_assignment(&graph, 4, assignment);

    let fast = run_distributed(
        Arc::clone(&graph),
        &machines,
        initial.clone(),
        &DistributedOptions::default(),
    );
    let slow = run_distributed(
        Arc::clone(&graph),
        &machines,
        initial,
        &DistributedOptions { latency: Duration::from_micros(200), ..Default::default() },
    );
    assert_eq!(fast.partition.assignment(), slow.partition.assignment());
}

/// The distributed equilibrium is a true Nash equilibrium and improves
/// the potential vs the initial partition.
#[test]
fn distributed_improves_and_stabilizes() {
    let mut rng = Pcg32::new(9);
    let graph = Arc::new(preferential_attachment(200, 2, &mut rng));
    let machines = MachineConfig::homogeneous(5);
    let initial = grow_partition(&graph, &machines, &mut rng);
    let c_before = global_cost::c0(&graph, &machines, &initial, 8.0);

    let report =
        run_distributed(Arc::clone(&graph), &machines, initial, &DistributedOptions::default());
    let c_after = global_cost::c0(&graph, &machines, &report.partition, 8.0);
    assert!(c_after <= c_before);

    let model = CostModel::new(&graph, machines.clone(), 8.0, Framework::A);
    for i in 0..200 {
        let (j, _) = model.dissatisfaction(&report.partition, i);
        assert!(j <= 1e-6, "node {i} dissatisfied after distributed run");
    }

    // Re-running from the equilibrium does nothing (idempotence).
    let again = run_distributed(
        Arc::clone(&graph),
        &machines,
        report.partition.clone(),
        &DistributedOptions::default(),
    );
    assert_eq!(again.transfers, 0);
    assert_eq!(again.partition.assignment(), report.partition.assignment());
}

/// TCP and in-process transports produce bit-identical
/// `DistributedReport`s: same equilibrium assignment, same transfer
/// count, same convergence flag, and byte-for-byte the same measured
/// `OverheadStats` — the wire accounting is exact on both transports.
#[test]
fn tcp_and_inproc_transports_bit_identical_reports() {
    for seed in [21u64, 22] {
        for fw in [Framework::A, Framework::B] {
            let mut rng = Pcg32::new(seed);
            let graph = Arc::new(preferential_attachment(120, 2, &mut rng));
            let machines = MachineConfig::from_speeds(&[0.15, 0.25, 0.35, 0.25]);
            let assignment: Vec<usize> = (0..120).map(|_| rng.index(4)).collect();
            let initial = Partition::from_assignment(&graph, 4, assignment);
            let opts = DistributedOptions { framework: fw, ..Default::default() };

            let inproc =
                run_distributed(Arc::clone(&graph), &machines, initial.clone(), &opts);
            let tcp = run_distributed_tcp_local(Arc::clone(&graph), &machines, initial, &opts)
                .expect("loopback mesh");

            assert_eq!(
                tcp.partition.assignment(),
                inproc.partition.assignment(),
                "seed {seed} fw {fw}: assignments differ across transports"
            );
            assert_eq!(tcp.transfers, inproc.transfers, "seed {seed} fw {fw}");
            assert_eq!(tcp.converged, inproc.converged, "seed {seed} fw {fw}");
            assert!(!tcp.timed_out);
            assert_eq!(
                tcp.overhead, inproc.overhead,
                "seed {seed} fw {fw}: overhead accounting differs across transports"
            );
        }
    }
}

/// §4.5 measured on real sockets: as the simulated graph grows 8x, the
/// synchronization bytes per transfer and the bytes of one
/// aggregate-state broadcast stay exactly flat (both are O(K) wire
/// quantities, independent of N).
#[test]
fn tcp_sync_overhead_independent_of_n() {
    let machines = MachineConfig::homogeneous(5);
    let mut per_transfer = Vec::new();
    let mut per_update = Vec::new();
    for n in [200usize, 1600] {
        let mut rng = Pcg32::new(7);
        let graph = Arc::new(preferential_attachment(n, 2, &mut rng));
        let initial = grow_partition(&graph, &machines, &mut rng);
        let report = run_distributed_tcp_local(
            Arc::clone(&graph),
            &machines,
            initial,
            &DistributedOptions::default(),
        )
        .expect("loopback mesh");
        assert!(report.converged);
        assert!(report.transfers > 0, "n={n}: no transfers at all");
        per_transfer.push(report.overhead.bytes_per_transfer(report.transfers as u64));
        per_update.push(report.overhead.bytes_per_regular_update());
    }
    assert_eq!(per_transfer[0], per_transfer[1], "bytes/transfer varies with N: {per_transfer:?}");
    assert_eq!(per_update[0], per_update[1], "bytes/RegularUpdate varies with N: {per_update:?}");
    // One transfer = 1 ReceiveNode + (K-2) RegularUpdates, exact sizes.
    let k = machines.count();
    assert_eq!(per_update[0], (33 + 8 * k) as f64);
    assert_eq!(per_transfer[0], (29 + (k - 2) * (33 + 8 * k)) as f64);
}

/// Named regression: a peer that dies mid-round (its endpoint drops,
/// closing its sockets) must not deadlock the survivors — every live
/// actor exits through `recv_timeout` within bounded time, on the real
/// TCP transport.
#[test]
fn tcp_peer_drop_during_round_times_out_cleanly() {
    let mut rng = Pcg32::new(13);
    let graph = preferential_attachment(60, 2, &mut rng);
    let machines = MachineConfig::homogeneous(3);
    let assignment: Vec<usize> = (0..60).map(|_| rng.index(3)).collect();
    let initial = Partition::from_assignment(&graph, 3, assignment);

    let (mut endpoints, _stats) = build_tcp_bus_local(3).expect("loopback mesh");
    drop(endpoints.pop().unwrap()); // machine 2 dies: its sockets close
    assert_ring_unwinds_on_dead_peer(
        endpoints,
        &graph,
        &machines,
        &initial,
        Duration::from_millis(200),
    );
}

/// Same regression on the in-process bus (the transports share one
/// timeout-aware receive path, so both must unwind).
#[test]
fn inproc_peer_drop_during_round_times_out_cleanly() {
    let mut rng = Pcg32::new(14);
    let graph = preferential_attachment(60, 2, &mut rng);
    let machines = MachineConfig::homogeneous(4);
    let initial = grow_partition(&graph, &machines, &mut rng);
    let (mut endpoints, _stats) = build_bus(4, Duration::ZERO);
    drop(endpoints.pop().unwrap());
    assert_ring_unwinds_on_dead_peer(
        endpoints,
        &graph,
        &machines,
        &initial,
        Duration::from_millis(150),
    );
}

/// Full multi-process smoke: spawn two real `gtip serve` worker
/// processes via the testkit harness, lead a refinement round over the
/// loopback mesh from this process, and require the result to be
/// bit-identical (assignment, transfers, overhead) to the in-process
/// run on the same fixture — the §4.5 protocol crossing genuine OS
/// process + socket boundaries.
#[test]
fn multiprocess_cluster_matches_inproc_refinement() {
    let mut rng = Pcg32::new(17);
    let graph = preferential_attachment(100, 2, &mut rng);
    let machines = MachineConfig::homogeneous(3);
    let assignment: Vec<usize> = (0..100).map(|_| rng.index(3)).collect();
    let initial = Partition::from_assignment(&graph, 3, assignment);
    let opts = DistributedOptions::default();

    let inproc = run_distributed(
        Arc::new(graph.clone()),
        &machines,
        initial.clone(),
        &opts,
    );

    let bin = std::path::Path::new(env!("CARGO_BIN_EXE_gtip"));
    let harness = TcpClusterHarness::spawn(bin, 3).expect("spawning serve workers");
    let mut leader = ClusterLeader::connect(
        &harness.peers,
        opts.clone(),
        Duration::from_secs(30),
    )
    .expect("leading the mesh");
    leader.setup(&graph, &machines).expect("broadcasting fixture");

    // Two rounds: the first refines to equilibrium, the second must be
    // an idempotent no-op — both bit-identical to in-process.
    let round1 = leader.refine(&graph, &machines, initial).expect("round 1");
    assert_eq!(round1.partition.assignment(), inproc.partition.assignment());
    assert_eq!(round1.transfers, inproc.transfers);
    assert_eq!(round1.overhead, inproc.overhead, "multi-process wire accounting diverged");
    assert!(round1.converged);

    let round2 = leader.refine(&graph, &machines, round1.partition.clone()).expect("round 2");
    assert_eq!(round2.transfers, 0);
    assert_eq!(round2.partition.assignment(), round1.partition.assignment());

    leader.shutdown().expect("goodbye");
    harness.join();
}

/// Degenerate pools: K=1 must trivially converge with zero transfers;
/// more machines than "useful" still terminates.
#[test]
fn degenerate_machine_pools() {
    let mut rng = Pcg32::new(11);
    let graph = Arc::new(preferential_attachment(60, 2, &mut rng));

    let one = MachineConfig::homogeneous(1);
    let p1 = Partition::all_on_machine(&graph, 1, 0);
    let r1 = run_distributed(Arc::clone(&graph), &one, p1, &DistributedOptions::default());
    assert!(r1.converged);
    assert_eq!(r1.transfers, 0);

    let many = MachineConfig::homogeneous(12);
    let pm = Partition::from_assignment(&graph, 12, (0..60).map(|i| i % 12).collect());
    let rm = run_distributed(Arc::clone(&graph), &many, pm, &DistributedOptions::default());
    assert!(rm.converged);
    rm.partition.validate(&graph).unwrap();
}

//! Integration tests of the distributed coordinator: protocol
//! correctness at scale, the measured §4.5 feasibility claim
//! (synchronization bytes per transfer independent of N), latency
//! robustness, and equivalence with the sequential engine.

use std::sync::Arc;
use std::time::Duration;

use gtip::coordinator::{run_distributed, DistributedOptions};
use gtip::game::cost::{CostModel, Framework};
use gtip::game::refine::{RefineEngine, RefineOptions};
use gtip::graph::generators::preferential_attachment;
use gtip::partition::initial::grow_partition;
use gtip::partition::{global_cost, MachineConfig, Partition};
use gtip::util::rng::Pcg32;

/// §4.5 measured: bytes of synchronization per transfer must be flat as
/// the simulated graph grows 8x.
#[test]
fn sync_overhead_independent_of_n() {
    let machines = MachineConfig::homogeneous(5);
    let mut bytes_per_transfer = Vec::new();
    for n in [200usize, 800, 1600] {
        let mut rng = Pcg32::new(7);
        let graph = Arc::new(preferential_attachment(n, 2, &mut rng));
        let initial = grow_partition(&graph, &machines, &mut rng);
        let report = run_distributed(
            Arc::clone(&graph),
            &machines,
            initial,
            &DistributedOptions::default(),
        );
        assert!(report.converged);
        assert!(report.transfers > 0, "n={n}: no transfers at all");
        bytes_per_transfer.push(report.overhead.bytes_per_transfer(report.transfers as u64));
    }
    let min = bytes_per_transfer.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = bytes_per_transfer.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        (max - min).abs() < 1e-9,
        "bytes/transfer varies with N: {bytes_per_transfer:?}"
    );
}

/// Distributed == sequential for several seeds and both frameworks.
#[test]
fn distributed_equals_sequential_many_seeds() {
    for seed in [1u64, 2, 3] {
        for fw in [Framework::A, Framework::B] {
            let mut rng = Pcg32::new(seed);
            let graph = Arc::new(preferential_attachment(150, 2, &mut rng));
            let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
            let assignment: Vec<usize> = (0..150).map(|_| rng.index(5)).collect();
            let initial = Partition::from_assignment(&graph, 5, assignment);

            let mut seq = RefineEngine::new(&graph, &machines, initial.clone(), 8.0, fw);
            let seq_report = seq.run(&RefineOptions::default());

            let dist = run_distributed(
                Arc::clone(&graph),
                &machines,
                initial,
                &DistributedOptions { framework: fw, ..Default::default() },
            );
            assert_eq!(
                dist.partition.assignment(),
                seq.partition().assignment(),
                "seed {seed} fw {fw}: assignments differ"
            );
            assert_eq!(dist.transfers, seq_report.transfers);
        }
    }
}

/// Determinism cross-check: with the same seed, graph, and round-robin
/// turn order, the distributed coordinator and the sequential
/// `RefineEngine` must produce *identical* final partitions AND
/// identical potentials — including across warm-started refinement
/// epochs with drifting node/edge weights (the closed `sim::dynamic`
/// loop relies on this equivalence to make its backends swappable).
#[test]
fn distributed_equals_sequential_partitions_and_potentials_under_drift() {
    for fw in [Framework::A, Framework::B] {
        let mut rng = Pcg32::new(31);
        let mut graph = preferential_attachment(120, 2, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let mut seq_part =
            Partition::from_assignment(&graph, 4, (0..120).map(|_| rng.index(4)).collect());
        let mut dist_part = seq_part.clone();

        // Three epochs of scripted weight drift, each refined from the
        // previous equilibrium by both implementations.
        for epoch in 0..3u64 {
            let weights: Vec<f64> =
                (0..120).map(|i| 1.0 + ((i as u64 * 7 + epoch * 13) % 11) as f64).collect();
            graph.set_node_weights(&weights);
            seq_part.rebuild_aggregates(&graph);
            dist_part.rebuild_aggregates(&graph);

            let mut seq = RefineEngine::new(&graph, &machines, seq_part, 8.0, fw);
            let seq_report = seq.run(&RefineOptions::default());
            let seq_potential = seq.potential();
            seq_part = seq.into_partition();

            let dist = run_distributed(
                Arc::new(graph.clone()),
                &machines,
                dist_part,
                &DistributedOptions { framework: fw, ..Default::default() },
            );
            dist_part = dist.partition;

            assert_eq!(
                seq_part.assignment(),
                dist_part.assignment(),
                "fw {fw} epoch {epoch}: assignments diverged"
            );
            assert_eq!(
                seq_report.transfers, dist.transfers,
                "fw {fw} epoch {epoch}: transfer counts diverged"
            );
            // Identical partitions must score identical potentials; also
            // pin the sequential engine's incremental potential to the
            // from-scratch evaluation.
            let (c0_seq, c0t_seq) = global_cost::both(&graph, &machines, &seq_part, 8.0);
            let (c0_dist, c0t_dist) = global_cost::both(&graph, &machines, &dist_part, 8.0);
            assert_eq!(c0_seq, c0_dist, "fw {fw} epoch {epoch}: C0 diverged");
            assert_eq!(c0t_seq, c0t_dist, "fw {fw} epoch {epoch}: C~0 diverged");
            let scratch = match fw {
                Framework::A => c0_seq,
                Framework::B => c0t_seq,
            };
            assert!(
                (seq_potential - scratch).abs() <= 1e-6 * (1.0 + scratch.abs()),
                "fw {fw} epoch {epoch}: incremental potential {seq_potential} vs scratch {scratch}"
            );
        }
    }
}

/// With injected per-message latency (remotely connected machines), the
/// protocol still converges to the same equilibrium.
#[test]
fn latency_does_not_change_result() {
    let mut rng = Pcg32::new(5);
    let graph = Arc::new(preferential_attachment(100, 2, &mut rng));
    let machines = MachineConfig::homogeneous(4);
    let assignment: Vec<usize> = (0..100).map(|_| rng.index(4)).collect();
    let initial = Partition::from_assignment(&graph, 4, assignment);

    let fast = run_distributed(
        Arc::clone(&graph),
        &machines,
        initial.clone(),
        &DistributedOptions::default(),
    );
    let slow = run_distributed(
        Arc::clone(&graph),
        &machines,
        initial,
        &DistributedOptions { latency: Duration::from_micros(200), ..Default::default() },
    );
    assert_eq!(fast.partition.assignment(), slow.partition.assignment());
}

/// The distributed equilibrium is a true Nash equilibrium and improves
/// the potential vs the initial partition.
#[test]
fn distributed_improves_and_stabilizes() {
    let mut rng = Pcg32::new(9);
    let graph = Arc::new(preferential_attachment(200, 2, &mut rng));
    let machines = MachineConfig::homogeneous(5);
    let initial = grow_partition(&graph, &machines, &mut rng);
    let c_before = global_cost::c0(&graph, &machines, &initial, 8.0);

    let report =
        run_distributed(Arc::clone(&graph), &machines, initial, &DistributedOptions::default());
    let c_after = global_cost::c0(&graph, &machines, &report.partition, 8.0);
    assert!(c_after <= c_before);

    let model = CostModel::new(&graph, machines.clone(), 8.0, Framework::A);
    for i in 0..200 {
        let (j, _) = model.dissatisfaction(&report.partition, i);
        assert!(j <= 1e-6, "node {i} dissatisfied after distributed run");
    }

    // Re-running from the equilibrium does nothing (idempotence).
    let again = run_distributed(
        Arc::clone(&graph),
        &machines,
        report.partition.clone(),
        &DistributedOptions::default(),
    );
    assert_eq!(again.transfers, 0);
    assert_eq!(again.partition.assignment(), report.partition.assignment());
}

/// Degenerate pools: K=1 must trivially converge with zero transfers;
/// more machines than "useful" still terminates.
#[test]
fn degenerate_machine_pools() {
    let mut rng = Pcg32::new(11);
    let graph = Arc::new(preferential_attachment(60, 2, &mut rng));

    let one = MachineConfig::homogeneous(1);
    let p1 = Partition::all_on_machine(&graph, 1, 0);
    let r1 = run_distributed(Arc::clone(&graph), &one, p1, &DistributedOptions::default());
    assert!(r1.converged);
    assert_eq!(r1.transfers, 0);

    let many = MachineConfig::homogeneous(12);
    let pm = Partition::from_assignment(&graph, 12, (0..60).map(|i| i % 12).collect());
    let rm = run_distributed(Arc::clone(&graph), &many, pm, &DistributedOptions::default());
    assert!(rm.converged);
    rm.partition.validate(&graph).unwrap();
}

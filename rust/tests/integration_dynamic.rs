//! Integration tests of the closed rebalancing loop (`sim::dynamic`) —
//! the paper's title scenario: under drifting workloads, re-measuring
//! loads and re-refining from the warm-start partition must beat a
//! frozen initial partition, and every refinement epoch must descend
//! the global potential.

use gtip::game::cost::Framework;
use gtip::sim::dynamic::{
    compare_frozen_vs_rebalanced, CompareReport, DynamicDriver, DynamicOptions, EstimatorKind,
    WeightEstimator,
};
use gtip::sim::engine::SimOptions;
use gtip::sim::scenario::ScenarioKind;
use gtip::util::testkit::ScenarioFixture;

fn loop_options(epoch_ticks: u64) -> DynamicOptions {
    DynamicOptions {
        sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
        epoch_ticks,
        ..Default::default()
    }
}

fn compare_for(kind: ScenarioKind, seed: u64) -> CompareReport {
    let fixture = ScenarioFixture::new(kind, seed)
        .nodes(120)
        .machines(4)
        .threads(110)
        .horizon(1_800)
        .build();
    compare_frozen_vs_rebalanced(
        &fixture.graph,
        &fixture.machines,
        &fixture.initial,
        &fixture.scenario.injections,
        WeightEstimator::ewma(0.6),
        &loop_options(200),
    )
}

/// Acceptance: with fixed seeds, the rebalanced run finishes the same
/// workload in fewer wall ticks than the frozen initial partition on at
/// least 3 of the 4 drifting scenarios.
#[test]
fn rebalancing_beats_frozen_on_most_scenarios() {
    let mut wins = 0;
    let mut lines = Vec::new();
    for kind in ScenarioKind::ALL {
        let r = compare_for(kind, 2011);
        assert!(!r.frozen.stats.truncated, "{kind:?}: frozen arm truncated");
        assert!(!r.rebalanced.stats.truncated, "{kind:?}: rebalanced arm truncated");
        assert!(r.rebalanced.refinements() > 0, "{kind:?}: loop never refined");
        let won = r.rebalanced.total_time() < r.frozen.total_time();
        lines.push(format!(
            "{:<8} frozen {:>7} rebalanced {:>7} speedup {:.2}x",
            kind.name(),
            r.frozen.total_time(),
            r.rebalanced.total_time(),
            r.speedup(),
        ));
        if won {
            wins += 1;
        }
    }
    assert!(
        wins >= 3,
        "rebalancing won only {wins}/4 scenarios:\n{}",
        lines.join("\n")
    );
}

/// Acceptance: every refinement epoch descends the global potential,
/// for both cost frameworks.
#[test]
fn every_epoch_descends_potential_both_frameworks() {
    for fw in [Framework::A, Framework::B] {
        let fixture = ScenarioFixture::new(ScenarioKind::HotspotShift, 3)
            .nodes(100)
            .machines(4)
            .threads(80)
            .horizon(1_200)
            .build();
        let options = DynamicOptions { framework: fw, ..loop_options(150) };
        let report = DynamicDriver::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            fixture.scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            options,
        )
        .run_owned();
        assert!(report.refinements() > 0, "{fw}: no refinement epochs");
        for e in &report.epochs {
            if let Some(r) = &e.refine {
                assert!(
                    r.potential_after <= r.potential_before + 1e-9 * (1.0 + r.potential_before.abs()),
                    "{fw}: epoch {} potential rose {} -> {}",
                    e.epoch,
                    r.potential_before,
                    r.potential_after
                );
                assert!(r.converged, "{fw}: epoch {} refinement did not converge", e.epoch);
            }
        }
    }
}

/// The closed loop is deterministic: identical fixture + options =>
/// identical tick counts, transfers, and epoch streams.
#[test]
fn closed_loop_is_deterministic() {
    let run = || {
        let fixture = ScenarioFixture::new(ScenarioKind::FlashCrowd, 17)
            .nodes(90)
            .machines(3)
            .threads(70)
            .horizon(1_000)
            .build();
        DynamicDriver::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            fixture.scenario.injections.clone(),
            WeightEstimator::ewma(0.5),
            loop_options(150),
        )
        .run_owned()
    };
    let a = run();
    let b = run();
    assert_eq!(a.stats.ticks, b.stats.ticks);
    assert_eq!(a.transfers, b.transfers);
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.events_processed, y.events_processed);
        assert_eq!(
            x.refine.as_ref().map(|r| r.transfers),
            y.refine.as_ref().map(|r| r.transfers)
        );
    }
}

/// All three estimator variants drive the loop to completion; smoothing
/// and hysteresis must not break draining or descent.
#[test]
fn all_estimators_complete_the_loop() {
    for kind in [
        EstimatorKind::Instantaneous,
        EstimatorKind::Ewma,
        EstimatorKind::Hysteresis,
    ] {
        let fixture = ScenarioFixture::new(ScenarioKind::DiurnalRamp, 5)
            .nodes(90)
            .machines(3)
            .threads(70)
            .horizon(1_000)
            .build();
        let injected = fixture.scenario.len() as u64;
        let report = DynamicDriver::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            fixture.scenario.injections.clone(),
            WeightEstimator::of_kind(kind),
            loop_options(150),
        )
        .run_owned();
        assert!(!report.stats.truncated, "{kind}: truncated");
        assert!(report.refinements() > 0, "{kind}: never refined");
        assert!(
            report.stats.events_processed >= injected,
            "{kind}: processed {} < injected {injected}",
            report.stats.events_processed
        );
        for e in &report.epochs {
            if let Some(r) = &e.refine {
                assert!(r.potential_after <= r.potential_before + 1e-9 * (1.0 + r.potential_before.abs()));
            }
        }
    }
}

/// Frequent rebalancing with a per-transfer migration charge still
/// accounts time correctly and cannot corrupt the run.
#[test]
fn migration_charges_do_not_break_the_loop() {
    let fixture = ScenarioFixture::new(ScenarioKind::FailureRejoin, 23)
        .nodes(90)
        .machines(3)
        .threads(70)
        .horizon(1_000)
        .build();
    let mut options = loop_options(100);
    options.ticks_per_transfer = 2;
    let report = DynamicDriver::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        fixture.scenario.injections.clone(),
        WeightEstimator::hysteresis(0.5, 0.25),
        options,
    )
    .run_owned();
    assert!(!report.stats.truncated);
    assert_eq!(report.migration_ticks, 2 * report.transfers as u64);
    assert_eq!(report.total_time(), report.stats.ticks + report.migration_ticks);
    // The accounting seam (PR 5): per-epoch wall windows bill the
    // migration stalls and tile the headline total exactly, and
    // throughput divides by the stalled window.
    assert_eq!(report.epochs.first().map(|e| e.wall_tick_start), Some(0));
    assert_eq!(report.epochs.last().map(|e| e.wall_tick_end), Some(report.total_time()));
    for e in &report.epochs {
        assert_eq!(
            e.wall_tick_end - e.wall_tick_start,
            (e.tick_end - e.tick_start) + e.migration_ticks
        );
        let window = (e.wall_tick_end - e.wall_tick_start).max(1);
        assert_eq!(e.throughput, e.events_processed as f64 / window as f64);
    }
}

/// The in-game migration charge (augmented game, DESIGN.md §9) at the
/// closed-loop level, asserting only what the theory guarantees: at a
/// moderate charge every epoch's raw descent, convergence, and the
/// churn bound `transfers <= ΔΦ / (2·c_mig)` hold; at a prohibitive
/// charge (1e12 — orders of magnitude above any raw gain the measured
/// weights can produce) the balancer provably freezes.
#[test]
fn in_game_charge_reduces_churn_end_to_end() {
    let run = |charge: f64| {
        let fixture = ScenarioFixture::new(ScenarioKind::HotspotShift, 29)
            .nodes(100)
            .machines(4)
            .threads(80)
            .horizon(1_200)
            .build();
        let mut options = loop_options(150);
        options.migration_charge = charge;
        DynamicDriver::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            fixture.scenario.injections.clone(),
            WeightEstimator::ewma(0.5),
            options,
        )
        .run_owned()
    };
    let free = run(0.0);
    assert!(free.transfers > 0, "fixture never migrated");
    let charged = run(40.0);
    for e in &charged.epochs {
        if let Some(r) = &e.refine {
            assert!(r.potential_after <= r.potential_before + 1e-9 * (1.0 + r.potential_before.abs()));
            assert!(r.converged);
            assert!(
                r.transfers as f64
                    <= (r.potential_before - r.potential_after) / (2.0 * 40.0) * (1.0 + 1e-9)
                        + 1e-9,
                "epoch {}: churn bound violated",
                e.epoch
            );
        }
    }
    let frozen_by_charge = run(1e12);
    assert_eq!(frozen_by_charge.transfers, 0, "a 1e12 charge must freeze the balancer");
}

//! Worker-join tests over real `gtip serve --join` processes
//! (DESIGN.md §10, grow direction): a 3-machine cluster loses a worker
//! (eviction to K−1), the dead machine's replacement asks to rejoin,
//! and the leader re-admits it at an epoch boundary — mesh extension,
//! `Setup` + snapshot catch-up, speeds renormalized to K+1 — after
//! which the run finishes at full strength and `admit-0000.snap`
//! replays from scratch to exactly the live run's final state. Edge
//! cases: a duplicate `Join` for a wire id that is still an active
//! member is rejected cleanly, and a joiner that dies during the admit
//! handshake leaves the survivors' run unharmed (rollback to K).

use std::time::Duration;

use gtip::coordinator::net::ClusterLeader;
use gtip::coordinator::DistributedOptions;
use gtip::sim::{
    DynamicDriver, DynamicOptions, RefineBackend, ScenarioKind, SimOptions, Snapshot,
    WeightEstimator,
};
use gtip::util::testkit::{ScenarioFixture, TcpClusterHarness};

fn kill_rejoin_fixture(seed: u64) -> gtip::util::testkit::BuiltFixture {
    ScenarioFixture::new(ScenarioKind::HotspotShift, seed)
        .nodes(120)
        .machines(3)
        .threads(60)
        .horizon(1600)
        .build()
}

fn leader_for(harness: &TcpClusterHarness) -> ClusterLeader {
    ClusterLeader::connect(
        &harness.peers,
        DistributedOptions { recv_timeout: Duration::from_secs(2), ..Default::default() },
        Duration::from_secs(30),
    )
    .expect("leading the mesh")
}

/// The full elasticity round trip: kill machine 2 mid-run (K=3 → 2),
/// relaunch it with `--join`, and finish back at K=3. The `Join`
/// necessarily arrives mid-epoch (the joiner binds as soon as the
/// victim's port frees, while the leader is still diagnosing the
/// death), so this also pins the deferral semantics: the request is
/// queued, not dropped, and admitted at the next boundary. The
/// `admit-0000.snap` the leader writes is the joiner's catch-up
/// payload; a sequential driver restored from it must reach exactly
/// the live run's final state.
#[test]
fn killed_worker_rejoins_and_run_finishes_at_full_strength() {
    let fixture = kill_rejoin_fixture(51);
    let dir = std::env::temp_dir().join(format!("gtip-join-happy-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 200_000, ..Default::default() },
        epoch_ticks: 200,
        backend: RefineBackend::Distributed,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };
    let bin = std::path::Path::new(env!("CARGO_BIN_EXE_gtip"));
    let harness = TcpClusterHarness::spawn_customized(bin, 3, |machine, cmd| {
        if machine == 2 {
            cmd.env("GTIP_SERVE_DIE", "epoch:1");
        }
    })
    .expect("spawning serve workers");
    let leader = leader_for(&harness);
    // Launch the replacement now: it retries binding machine 2's
    // address until the victim dies and releases the port, then dials
    // the leader and queues its Join — no scripted sleep needed.
    let mut joiner = harness.spawn_joiner(bin, 2, |_| {}).expect("spawning the joiner");

    let mut driver = DynamicDriver::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        fixture.scenario.injections.clone(),
        WeightEstimator::instantaneous(),
        options,
    );
    driver.attach_cluster(leader).expect("broadcasting fixture");
    let report = driver.try_run().expect("the run must survive the death and the rejoin");

    assert_eq!(report.recoveries(), 1, "the planted death recovers once");
    assert_eq!(report.admissions(), 1, "the rejoin is admitted once");
    let admission = report
        .epochs
        .iter()
        .find_map(|e| e.admission.as_ref())
        .expect("an admission record on the admitting epoch");
    assert_eq!(admission.joined_wire_id, 2, "wire id 2 rejoined");
    assert_eq!(admission.machines_before, 2);
    assert_eq!(admission.machines_after, 3);
    assert_eq!(driver.machines().count(), 3, "the fleet must be back at full strength");
    assert!(
        (driver.machines().speeds().iter().sum::<f64>() - 1.0).abs() < 1e-9,
        "speeds must be renormalized to the grown fleet"
    );
    assert!(!report.stats.truncated, "the workload must drain fully after the rejoin");
    let final_assignment = driver.engine().partition().assignment().to_vec();
    assert!(final_assignment.iter().all(|&m| m < 3));
    // The admitted machine actually carries load again by the end:
    // refinement migrates toward the empty machine (Thm 4.1 descent
    // from any feasible start), which is the whole point of growing.
    let admitted_machine = admission.joined_machine;
    assert!(
        final_assignment.iter().any(|&m| m == admitted_machine),
        "no LP migrated to the re-admitted machine"
    );

    // The victim died on purpose; the original survivor and the
    // joiner both exit cleanly on the leader's Goodbye.
    harness.join_expecting_deaths(&[2]);
    let joiner_status = joiner.wait().expect("waiting on the joiner");
    assert!(joiner_status.success(), "the joiner should serve to Goodbye, got {joiner_status}");

    // The admission checkpoint is canonical and replays from scratch
    // to the live run's exact final state.
    let snap_path = dir.join("admit-0000.snap");
    let bytes = std::fs::read(&snap_path).expect("admit-0000.snap must have been written");
    let snap = Snapshot::decode(&bytes).expect("admit-0000.snap must decode");
    assert_eq!(snap.encode(), bytes, "admit-0000.snap is not canonical bytes");
    assert_eq!(snap.machine_count(), 3, "the admission snapshot captures the grown fleet");
    let graph = snap.build_graph();
    let mut restored = DynamicDriver::from_snapshot(
        &graph,
        &snap,
        WeightEstimator::instantaneous(),
        DynamicOptions { epoch_ticks: 200, ..Default::default() },
    );
    let restored_report = restored.run();
    assert_eq!(restored_report.stats, report.stats);
    assert_eq!(restored_report.total_time(), report.total_time());
    assert_eq!(restored.engine().partition().assignment(), &final_assignment[..]);
    assert_eq!(restored.machines().speeds(), driver.machines().speeds());

    let _ = std::fs::remove_dir_all(&dir);
}

/// A `Join` carrying the wire id of a machine that is still an active
/// member must be rejected cleanly: the impostor exits with an error
/// (not the intentional-death code), and the run never grows. The
/// impostor gets a peers list whose slot-2 address is a free port so
/// it can bind; everything else about its handshake is legitimate.
#[test]
fn duplicate_join_from_active_wire_id_is_rejected() {
    let fixture = kill_rejoin_fixture(53);
    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 200_000, ..Default::default() },
        epoch_ticks: 200,
        backend: RefineBackend::Distributed,
        ..Default::default()
    };
    let bin = std::path::Path::new(env!("CARGO_BIN_EXE_gtip"));
    let harness = TcpClusterHarness::spawn(bin, 3).expect("spawning serve workers");
    let leader = leader_for(&harness);

    // Same leader address, but slot 2 rerouted to a free port: the
    // impostor can bind and present itself as wire id 2 while the
    // real machine 2 is alive and well.
    let mut impostor_peers = harness.peers.clone();
    impostor_peers[2] = TcpClusterHarness::reserve_loopback_peers(1).remove(0);
    let mut impostor = std::process::Command::new(bin)
        .args([
            "serve",
            "--machine-id",
            "2",
            "--peers",
            &impostor_peers.join(","),
            "--join",
            "--connect-timeout-ms",
            "4000",
            "--admit-window-ms",
            "1000",
        ])
        .stdout(std::process::Stdio::null())
        .spawn()
        .expect("spawning the impostor");

    let mut driver = DynamicDriver::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        fixture.scenario.injections.clone(),
        WeightEstimator::instantaneous(),
        options,
    );
    driver.attach_cluster(leader).expect("broadcasting fixture");
    let report = driver.try_run().expect("the healthy run must be unaffected");

    assert_eq!(report.recoveries(), 0, "nobody died");
    assert_eq!(report.admissions(), 0, "an active wire id must never be re-admitted");
    assert_eq!(driver.machines().count(), 3, "the fleet must not change");
    assert!(!report.stats.truncated);
    harness.join();

    let status = impostor.wait().expect("waiting on the impostor");
    assert!(!status.success(), "the duplicate join must fail");
    assert_ne!(status.code(), Some(86), "rejection is an error exit, not a planted death");
}

/// A joiner that dies in the middle of the admit handshake (on
/// receiving `Admit`, before acking) must not take the survivors with
/// it: the leader rolls the admission back and the run finishes at
/// K−1 with zero admissions on the books.
#[test]
fn joiner_death_during_admit_leaves_survivors_unharmed() {
    let fixture = kill_rejoin_fixture(55);
    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 200_000, ..Default::default() },
        epoch_ticks: 200,
        backend: RefineBackend::Distributed,
        ..Default::default()
    };
    let bin = std::path::Path::new(env!("CARGO_BIN_EXE_gtip"));
    let harness = TcpClusterHarness::spawn_customized(bin, 3, |machine, cmd| {
        if machine == 2 {
            cmd.env("GTIP_SERVE_DIE", "epoch:1");
        }
    })
    .expect("spawning serve workers");
    let leader = leader_for(&harness);
    let mut joiner = harness
        .spawn_joiner(bin, 2, |cmd| {
            cmd.env("GTIP_SERVE_DIE", "admit");
        })
        .expect("spawning the doomed joiner");

    let mut driver = DynamicDriver::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        fixture.scenario.injections.clone(),
        WeightEstimator::instantaneous(),
        options,
    );
    driver.attach_cluster(leader).expect("broadcasting fixture");
    let report = driver.try_run().expect("the survivors' run must outlive the doomed joiner");

    assert_eq!(report.recoveries(), 1, "only the planted death recovers");
    assert_eq!(report.admissions(), 0, "the aborted admission must not be recorded");
    assert_eq!(driver.machines().count(), 2, "the fleet stays at the survivors");
    assert!(!report.stats.truncated, "the run must drain at K-1 after the rollback");
    assert!(driver.engine().partition().assignment().iter().all(|&m| m < 2));

    harness.join_expecting_deaths(&[2]);
    let joiner_status = joiner.wait().expect("waiting on the doomed joiner");
    assert_eq!(
        joiner_status.code(),
        Some(86),
        "the joiner must have died on Admit as planted, got {joiner_status}"
    );
}

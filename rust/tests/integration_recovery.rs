//! Kill-a-worker recovery tests over real `gtip serve` processes
//! (DESIGN.md §10): a worker is planted with a `GTIP_SERVE_DIE` fault
//! and murdered at a chosen protocol state — right after `Setup`,
//! mid-epoch on an `EpochBegin`, or at the `RoundStats` barrier — and
//! the closed loop must restore from the last epoch-boundary
//! checkpoint, evict exactly the dead machine, and finish the run with
//! the K−1 survivors instead of unwinding. The mid-epoch case also
//! pins the checkpoint substrate: every emitted `.snap` re-encodes
//! byte-identically, and a fresh driver restored from
//! `recovery-0000.snap` reaches exactly the live run's final state.
//! The double-death case kills two workers in different epochs and
//! asserts each recovery keeps its own replay point
//! (`recovery-0000.snap` / `recovery-0001.snap`).

use std::path::PathBuf;
use std::time::Duration;

use gtip::coordinator::net::ClusterLeader;
use gtip::coordinator::DistributedOptions;
use gtip::partition::global_cost;
use gtip::sim::{
    DynamicDriver, DynamicOptions, DynamicReport, RefineBackend, ScenarioKind, SimOptions,
    Snapshot, WeightEstimator,
};
use gtip::util::testkit::{ScenarioFixture, TcpClusterHarness};

/// Everything a kill scenario leaves behind for further assertions.
struct KillRun {
    report: DynamicReport,
    /// Final LP assignment of the recovered live run.
    final_assignment: Vec<usize>,
    /// Final (renormalized) survivor speeds.
    final_speeds: Vec<f64>,
    checkpoint_dir: PathBuf,
}

/// Run the closed loop over a 3-machine cluster with `GTIP_SERVE_DIE`
/// planted in `victim`, and assert the shared recovery contract: the
/// run completes, exactly one epoch recovered, exactly the victim was
/// evicted, the fleet shrank 3 → 2, and the victim's process exited
/// with the intentional-death code while the survivor exited cleanly.
fn run_with_planted_death(tag: &str, die: &str, victim: usize, seed: u64) -> KillRun {
    let fixture = ScenarioFixture::new(ScenarioKind::HotspotShift, seed)
        .nodes(120)
        .machines(3)
        .threads(60)
        .horizon(900)
        .build();
    let dir = std::env::temp_dir().join(format!("gtip-recovery-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 200_000, ..Default::default() },
        epoch_ticks: 200,
        backend: RefineBackend::Distributed,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };

    let bin = std::path::Path::new(env!("CARGO_BIN_EXE_gtip"));
    let harness = TcpClusterHarness::spawn_customized(bin, 3, |machine, cmd| {
        if machine == victim {
            cmd.env("GTIP_SERVE_DIE", die);
        }
    })
    .expect("spawning serve workers");
    let leader = ClusterLeader::connect(
        &harness.peers,
        DistributedOptions { recv_timeout: Duration::from_secs(2), ..Default::default() },
        Duration::from_secs(30),
    )
    .expect("leading the mesh");

    let mut driver = DynamicDriver::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        fixture.scenario.injections.clone(),
        WeightEstimator::instantaneous(),
        options,
    );
    driver.attach_cluster(leader).expect("broadcasting fixture");
    let report = driver.try_run().expect("the run must survive the planted worker death");

    assert_eq!(report.recoveries(), 1, "{tag}: exactly one epoch should have recovered");
    let recovery = report
        .epochs
        .iter()
        .find_map(|e| e.recovery.as_ref())
        .expect("a recovery record on the recovered epoch");
    assert_eq!(recovery.dead_machines, vec![victim], "{tag}: wrong machine evicted");
    assert_eq!(recovery.machines_before, 3, "{tag}");
    assert_eq!(recovery.machines_after, 2, "{tag}");
    assert_eq!(driver.machines().count(), 2, "{tag}: fleet must shrink to the survivors");
    assert!(
        (driver.machines().speeds().iter().sum::<f64>() - 1.0).abs() < 1e-9,
        "{tag}: survivor speeds must be renormalized"
    );
    assert!(!report.stats.truncated, "{tag}: the workload must drain fully after recovery");
    // Every surviving LP landed on a surviving machine.
    let assignment = driver.engine().partition().assignment().to_vec();
    assert!(assignment.iter().all(|&m| m < 2), "{tag}: LP homed on an evicted machine");

    harness.join_expecting_deaths(&[victim]);
    KillRun {
        final_speeds: driver.machines().speeds().to_vec(),
        final_assignment: assignment,
        report,
        checkpoint_dir: dir,
    }
}

/// A worker killed on `EpochBegin` of the *second* refinement round:
/// recovery restores the mid-run checkpoint (not the initial state),
/// and the `.snap` artifacts it leaves behind are canonical — each one
/// byte-stable through decode/encode, and `recovery-0000.snap` replays
/// to exactly the live run's final state on a from-scratch driver.
#[test]
fn worker_death_mid_epoch_recovers_from_checkpoint() {
    let run = run_with_planted_death("mid-epoch", "epoch:1", 1, 41);
    assert!(
        run.report.epochs[1].recovery.is_some(),
        "the death was planted in epoch 1's round"
    );
    assert!(run.report.epochs[0].recovery.is_none(), "epoch 0 completed at full strength");

    // Save -> load -> save is byte-identical for every emitted file.
    let mut snaps = 0;
    for entry in std::fs::read_dir(&run.checkpoint_dir).expect("checkpoint dir must exist") {
        let path = entry.unwrap().path();
        let bytes = std::fs::read(&path).unwrap();
        let snap = Snapshot::decode(&bytes)
            .unwrap_or_else(|e| panic!("{} must decode: {e}", path.display()));
        assert_eq!(snap.encode(), bytes, "{} is not canonical bytes", path.display());
        snaps += 1;
    }
    assert!(snaps >= 3, "expected per-epoch checkpoints plus recovery-0000.snap, found {snaps}");

    // From-scratch restore: a sequential driver resumed from
    // recovery-0000.snap must deterministically reach the same final
    // state as the recovered live run (stats, costs, assignment).
    let snap = Snapshot::read_from(&run.checkpoint_dir.join("recovery-0000.snap"))
        .expect("recovery-0000.snap must have been written");
    assert_eq!(snap.machine_count(), 2, "recovery-0000.snap captures the shrunken fleet");
    let graph = snap.build_graph();
    let mut restored = DynamicDriver::from_snapshot(
        &graph,
        &snap,
        WeightEstimator::instantaneous(),
        DynamicOptions { epoch_ticks: 200, ..Default::default() },
    );
    let restored_report = restored.run();
    assert_eq!(restored_report.stats, run.report.stats);
    assert_eq!(restored_report.transfers, run.report.transfers);
    assert_eq!(restored_report.total_time(), run.report.total_time());
    assert_eq!(restored.engine().partition().assignment(), &run.final_assignment[..]);
    assert_eq!(restored.machines().speeds(), &run.final_speeds[..]);
    let c_restored =
        global_cost::c0(&graph, restored.machines(), restored.engine().partition(), 8.0);
    let c_live = global_cost::c0(
        &graph,
        restored.machines(),
        &gtip::partition::Partition::from_assignment(&graph, 2, run.final_assignment.clone()),
        8.0,
    );
    assert_eq!(c_restored.to_bits(), c_live.to_bits(), "final global cost diverged");

    let _ = std::fs::remove_dir_all(&run.checkpoint_dir);
}

/// A worker that dies straight after validating `Setup` — before it
/// ever plays a round. The very first refinement diagnoses it (either
/// by the failed `EpochBegin` write or by its silence) and the run
/// completes at K−1 from the epoch-0 checkpoint.
#[test]
fn worker_death_after_setup_recovers_on_first_epoch() {
    let run = run_with_planted_death("setup", "setup", 1, 43);
    assert!(
        run.report.epochs[0].recovery.is_some(),
        "the first refinement must have diagnosed the setup-time death"
    );
    let _ = std::fs::remove_dir_all(&run.checkpoint_dir);
}

/// A worker that plays its round to completion and dies *at the
/// RoundStats barrier*. The barrier has already consumed the other
/// worker's report when it fails, so this pins the
/// evidence-preserving diagnosis: the worker whose stats were consumed
/// must NOT be evicted alongside the one that never reported.
#[test]
fn worker_death_at_stats_barrier_recovers() {
    let run = run_with_planted_death("stats", "stats", 2, 45);
    assert!(
        run.report.epochs[0].recovery.is_some(),
        "the first refinement must have diagnosed the barrier-time death"
    );
    let _ = std::fs::remove_dir_all(&run.checkpoint_dir);
}

/// Two workers die in *different* epochs of one run (K=4 → 3 → 2).
/// Each recovery must keep its own replay point: `recovery-0000.snap`
/// (fleet at 3) and `recovery-0001.snap` (fleet at 2) both exist, are
/// canonical, and the *last* one replays from scratch to exactly the
/// live run's final state. Before the ordinal naming, the second
/// recovery silently overwrote the first's file, so only the last
/// recovery was ever reproducible.
#[test]
fn two_deaths_keep_both_recovery_replay_points() {
    let fixture = ScenarioFixture::new(ScenarioKind::HotspotShift, 47)
        .nodes(120)
        .machines(4)
        .threads(60)
        .horizon(1600)
        .build();
    let dir = std::env::temp_dir().join(format!("gtip-recovery-double-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 200_000, ..Default::default() },
        epoch_ticks: 200,
        backend: RefineBackend::Distributed,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };
    let bin = std::path::Path::new(env!("CARGO_BIN_EXE_gtip"));
    let harness = TcpClusterHarness::spawn_customized(bin, 4, |machine, cmd| {
        if machine == 1 {
            cmd.env("GTIP_SERVE_DIE", "epoch:1");
        }
        if machine == 3 {
            cmd.env("GTIP_SERVE_DIE", "epoch:3");
        }
    })
    .expect("spawning serve workers");
    let leader = ClusterLeader::connect(
        &harness.peers,
        DistributedOptions { recv_timeout: Duration::from_secs(2), ..Default::default() },
        Duration::from_secs(30),
    )
    .expect("leading the mesh");
    let mut driver = DynamicDriver::new(
        &fixture.graph,
        fixture.machines.clone(),
        fixture.initial.clone(),
        fixture.scenario.injections.clone(),
        WeightEstimator::instantaneous(),
        options,
    );
    driver.attach_cluster(leader).expect("broadcasting fixture");
    let report = driver.try_run().expect("the run must survive both planted deaths");
    assert_eq!(report.recoveries(), 2, "each death recovers in its own epoch");
    assert_eq!(driver.machines().count(), 2, "fleet shrank 4 -> 3 -> 2");
    assert!(!report.stats.truncated, "the workload must drain fully after both recoveries");
    harness.join_expecting_deaths(&[1, 3]);

    // Both replay points survive, each canonical, each at its fleet.
    let first = Snapshot::read_from(&dir.join("recovery-0000.snap"))
        .expect("the first recovery's replay point must not be overwritten");
    assert_eq!(first.encode().len(), std::fs::read(dir.join("recovery-0000.snap")).unwrap().len());
    assert_eq!(first.machine_count(), 3, "the first recovery left K=3");
    let second = Snapshot::read_from(&dir.join("recovery-0001.snap"))
        .expect("the second recovery must write its own ordinal");
    assert_eq!(second.machine_count(), 2, "the second recovery left K=2");

    // The later replay point reaches exactly the live final state.
    let graph = second.build_graph();
    let mut restored = DynamicDriver::from_snapshot(
        &graph,
        &second,
        WeightEstimator::instantaneous(),
        DynamicOptions { epoch_ticks: 200, ..Default::default() },
    );
    let restored_report = restored.run();
    assert_eq!(restored_report.stats, report.stats);
    assert_eq!(restored_report.total_time(), report.total_time());
    assert_eq!(
        restored.engine().partition().assignment(),
        driver.engine().partition().assignment()
    );

    // The earlier one still replays to a clean finish — at K=3: a
    // sequential replay does not re-experience the second death.
    let graph3 = first.build_graph();
    let mut early = DynamicDriver::from_snapshot(
        &graph3,
        &first,
        WeightEstimator::instantaneous(),
        DynamicOptions { epoch_ticks: 200, ..Default::default() },
    );
    let early_report = early.run();
    assert!(!early_report.stats.truncated, "the first replay point must drain at K=3");
    assert_eq!(early.machines().count(), 3);

    let _ = std::fs::remove_dir_all(&dir);
}

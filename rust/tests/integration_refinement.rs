//! Integration tests across graph generation, initial partitioning,
//! refinement, baselines and the meta-heuristic extensions — exercising
//! the full partitioning pipeline a user would run.

use gtip::game::annealing::{anneal_then_refine, AnnealOptions};
use gtip::game::cluster::{cluster_escape, ClusterOptions};
use gtip::game::cost::Framework;
use gtip::game::refine::{RefineEngine, RefineOptions};
use gtip::graph::generators::{generate, GraphFamily};
use gtip::partition::baselines;
use gtip::partition::initial::grow_partition;
use gtip::partition::{global_cost, MachineConfig};
use gtip::util::rng::Pcg32;

/// Full pipeline: generate → initial partition → refine → equilibrium,
/// across all graph families.
#[test]
fn pipeline_all_graph_families() {
    for (fam, n) in [
        (GraphFamily::Table1, 230),
        (GraphFamily::PreferentialAttachment, 230),
        (GraphFamily::Geometric, 230),
        (GraphFamily::ErdosRenyi, 150),
    ] {
        let mut rng = Pcg32::new(11);
        let graph = generate(fam, n, &mut rng);
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let initial = grow_partition(&graph, &machines, &mut rng);
        let c0_before = global_cost::c0(&graph, &machines, &initial, 8.0);
        let mut engine = RefineEngine::new(&graph, &machines, initial, 8.0, Framework::A);
        let report = engine.run(&RefineOptions::default());
        assert!(report.converged, "{fam:?} did not converge");
        assert!(
            report.final_potential <= c0_before,
            "{fam:?}: refinement worsened C0"
        );
        engine.validate().unwrap();
    }
}

/// The game-theoretic method beats all baselines on its own objective.
#[test]
fn beats_baselines_on_c0() {
    let mut rng = Pcg32::new(13);
    let graph = generate(GraphFamily::Table1, 230, &mut rng);
    let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
    let mu = 8.0;

    let refined = {
        let initial = grow_partition(&graph, &machines, &mut rng);
        let mut engine = RefineEngine::new(&graph, &machines, initial, mu, Framework::A);
        let _ = engine.run(&RefineOptions::default());
        global_cost::c0(&graph, &machines, engine.partition(), mu)
    };

    let random = global_cost::c0(
        &graph,
        &machines,
        &baselines::random_partition(&graph, 5, &mut rng),
        mu,
    );
    let rr = global_cost::c0(&graph, &machines, &baselines::round_robin(&graph, 5), mu);
    let greedy = global_cost::c0(&graph, &machines, &baselines::greedy_load(&graph, &machines), mu);
    let cut_only = {
        let mut p = baselines::random_partition(&graph, 5, &mut rng);
        let _ = baselines::cut_only_gain(&graph, &mut p);
        global_cost::c0(&graph, &machines, &p, mu)
    };

    assert!(refined < random, "refined {refined} vs random {random}");
    assert!(refined < rr, "refined {refined} vs round-robin {rr}");
    assert!(refined < cut_only, "refined {refined} vs cut-only {cut_only}");
    // Greedy-load is strong on the load term but blind to the cut; the
    // game method must still match or beat it on the combined objective.
    assert!(
        refined <= greedy * 1.001,
        "refined {refined} vs greedy-load {greedy}"
    );
}

/// Cut-only baseline (Nandy–Loucks-style) achieves a lower *cut* but a
/// worse *combined* objective — the precise gap the paper motivates (§2).
#[test]
fn cut_only_tradeoff_visible() {
    let mut rng = Pcg32::new(17);
    let graph = generate(GraphFamily::PreferentialAttachment, 200, &mut rng);
    let machines = MachineConfig::homogeneous(4);

    let initial = grow_partition(&graph, &machines, &mut rng);
    let mut game_part = initial.clone();
    {
        let mut engine =
            RefineEngine::new(&graph, &machines, game_part, 8.0, Framework::A);
        let _ = engine.run(&RefineOptions::default());
        game_part = engine.into_partition();
    }
    let mut cut_part = initial;
    let _ = baselines::cut_only_gain(&graph, &mut cut_part);

    let game_imbalance = game_part.imbalance(&machines);
    let cut_imbalance = cut_part.imbalance(&machines);
    assert!(
        game_imbalance < cut_imbalance + 1e-9,
        "game imbalance {game_imbalance} should beat cut-only {cut_imbalance}"
    );
}

/// §4.4 extensions stack: anneal → refine → cluster escape, never
/// worsening the potential at any stage.
#[test]
fn extension_pipeline_monotone() {
    let mut rng = Pcg32::new(19);
    let graph = generate(GraphFamily::Table1, 150, &mut rng);
    let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
    let mu = 8.0;

    let initial = grow_partition(&graph, &machines, &mut rng);
    let c_initial = global_cost::c0(&graph, &machines, &initial, mu);

    let (mut part, c_refined) = anneal_then_refine(
        &graph,
        &machines,
        initial,
        mu,
        Framework::A,
        &AnnealOptions::default(),
        &mut rng,
    );
    assert!(c_refined <= c_initial);

    let moves =
        cluster_escape(&graph, &machines, &mut part, mu, Framework::A, &ClusterOptions::default());
    let c_final = global_cost::c0(&graph, &machines, &part, mu);
    let predicted: f64 = moves.iter().map(|m| m.delta).sum();
    assert!((c_final - c_refined - predicted).abs() < 1e-6 * (1.0 + c_refined.abs()));
    assert!(c_final <= c_refined + 1e-9);
    part.validate(&graph).unwrap();
}

/// Dynamic weights: re-weighting the same graph and re-refining from the
/// previous equilibrium converges again and ends at a (new) equilibrium.
#[test]
fn dynamic_reweighting_epochs() {
    let mut rng = Pcg32::new(23);
    let mut graph = generate(GraphFamily::PreferentialAttachment, 200, &mut rng);
    let machines = MachineConfig::homogeneous(4);
    let mut part = grow_partition(&graph, &machines, &mut rng);

    for epoch in 0..5 {
        // Synthetic "hot spot" weights: a moving window of heavy nodes.
        let w: Vec<f64> = (0..200)
            .map(|i| if (i + epoch * 40) % 200 < 40 { 10.0 } else { 1.0 })
            .collect();
        graph.set_node_weights(&w);
        part.rebuild_aggregates(&graph);
        let mut engine = RefineEngine::new(&graph, &machines, part, 8.0, Framework::A);
        let report = engine.run(&RefineOptions::default());
        assert!(report.converged, "epoch {epoch} did not converge");
        engine.validate().unwrap();
        part = engine.into_partition();
    }
}

/// Determinism: the entire pipeline is reproducible from the seed.
#[test]
fn pipeline_deterministic() {
    let run = || {
        let mut rng = Pcg32::new(99);
        let graph = generate(GraphFamily::Table1, 120, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let initial = grow_partition(&graph, &machines, &mut rng);
        let mut engine = RefineEngine::new(&graph, &machines, initial, 8.0, Framework::A);
        let report = engine.run(&RefineOptions::default());
        (report.transfers, engine.partition().assignment().to_vec())
    };
    assert_eq!(run(), run());
}

//! Integration: the AOT HLO artifacts (python/jax/Pallas) executed via
//! PJRT must agree with the native Rust evaluator — the cross-layer
//! correctness contract of the three-layer architecture.
//!
//! Requires `make artifacts`; tests skip (with a loud message) if the
//! artifacts directory is absent so plain `cargo test` stays green in a
//! fresh checkout. The whole file is additionally gated on the `pjrt`
//! cargo feature (the executor needs the vendored `xla` crate).
#![cfg(feature = "pjrt")]

use gtip::game::cost::{CostModel, Framework};
use gtip::graph::generators::{preferential_attachment, table1_graph, WeightModel};
use gtip::partition::{MachineConfig, Partition};
use gtip::runtime::cost_eval::{max_rel_error_vs_native, PjrtCostEvaluator};
use gtip::util::rng::Pcg32;

fn evaluator() -> Option<PjrtCostEvaluator> {
    match PjrtCostEvaluator::from_default_dir() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP integration_runtime: {e} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn pjrt_matches_native_paper_shape() {
    let Some(mut eval) = evaluator() else { return };
    let mut rng = Pcg32::new(1);
    let g = table1_graph(230, 3, 6, WeightModel::default(), &mut rng);
    let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
    let assignment: Vec<usize> = (0..230).map(|_| rng.index(5)).collect();
    let part = Partition::from_assignment(&g, 5, assignment);
    let out = eval.evaluate(&g, &machines, &part, 8.0).unwrap();
    assert_eq!(out.n, 230);
    assert_eq!(out.k, 5);
    let err = max_rel_error_vs_native(&g, &machines, &part, 8.0, &out);
    assert!(err < 1e-3, "PJRT vs native rel error {err}");
}

#[test]
fn pjrt_best_moves_agree_with_native() {
    let Some(mut eval) = evaluator() else { return };
    let mut rng = Pcg32::new(2);
    let g = preferential_attachment(300, 2, &mut rng);
    let machines = MachineConfig::homogeneous(4);
    let assignment: Vec<usize> = (0..300).map(|_| rng.index(4)).collect();
    let part = Partition::from_assignment(&g, 4, assignment);
    let out = eval.evaluate(&g, &machines, &part, 4.0).unwrap();

    let model = CostModel::new(&g, machines.clone(), 4.0, Framework::A);
    for i in 0..300 {
        let (native_j, _) = model.dissatisfaction(&part, i);
        let pjrt_j = out.dissat_a[i] as f64;
        assert!(
            (native_j - pjrt_j).abs() < 1e-2 * (1.0 + native_j.abs()),
            "node {i}: native J={native_j} pjrt J={pjrt_j}"
        );
        // Best move must be cost-equivalent (ties may differ).
        let chosen = out.best_a[i] as usize;
        assert!(chosen < 4, "argmin leaked into padding: {chosen}");
        let (_, native_best_cost) = model.best_response(&part, i);
        let chosen_cost = model.node_cost(&part, i, chosen);
        assert!(
            (chosen_cost - native_best_cost).abs() < 1e-2 * (1.0 + native_best_cost.abs()),
            "node {i}: pjrt argmin {chosen} cost {chosen_cost} vs native best {native_best_cost}"
        );
    }
}

#[test]
fn pjrt_size_ladder_picks_fitting_artifact() {
    let Some(mut eval) = evaluator() else { return };
    let mut rng = Pcg32::new(3);
    // 300 nodes won't fit n=256; must transparently use n=512.
    let g = preferential_attachment(300, 2, &mut rng);
    let machines = MachineConfig::homogeneous(3);
    let part = Partition::from_assignment(&g, 3, (0..300).map(|i| i % 3).collect());
    let out = eval.evaluate(&g, &machines, &part, 8.0).unwrap();
    assert_eq!(out.n, 300);
    let err = max_rel_error_vs_native(&g, &machines, &part, 8.0, &out);
    assert!(err < 1e-3, "rel error {err}");
}

#[test]
fn pjrt_rejects_oversized_problems() {
    let Some(mut eval) = evaluator() else { return };
    let max = eval.max_nodes();
    let mut rng = Pcg32::new(4);
    let g = preferential_attachment(max + 10, 2, &mut rng);
    let machines = MachineConfig::homogeneous(2);
    let part = Partition::from_assignment(&g, 2, (0..max + 10).map(|i| i % 2).collect());
    assert!(eval.evaluate(&g, &machines, &part, 1.0).is_err());
}

#[test]
fn pjrt_globals_track_refinement_descent() {
    // Refine natively; the PJRT-reported C0 must descend too.
    let Some(mut eval) = evaluator() else { return };
    let mut rng = Pcg32::new(5);
    let g = table1_graph(150, 3, 6, WeightModel::default(), &mut rng);
    let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
    let part = Partition::from_assignment(&g, 5, (0..150).map(|_| rng.index(5)).collect());

    let before = eval.evaluate(&g, &machines, &part, 8.0).unwrap();
    let mut engine =
        gtip::game::refine::RefineEngine::new(&g, &machines, part, 8.0, Framework::A);
    let _ = engine.run(&gtip::game::refine::RefineOptions::default());
    let after = eval.evaluate(&g, &machines, engine.partition(), 8.0).unwrap();
    assert!(
        after.c0 < before.c0,
        "refinement must descend C0 as seen through PJRT: {} -> {}",
        before.c0,
        after.c0
    );
}

//! Integration tests of the optimistic PDES archetype + dynamic
//! refinement driver: conservation, causality, the partition-quality →
//! simulation-time causal chain, and failure injection (adversarial
//! partitions, pathological workloads).

use gtip::game::cost::Framework;
use gtip::graph::generators::{generate, preferential_attachment, GraphFamily};
use gtip::graph::GraphBuilder;
use gtip::partition::{MachineConfig, Partition};
use gtip::sim::driver::{run_dynamic, DriverOptions};
use gtip::sim::engine::{Injection, SimEngine, SimOptions};
use gtip::sim::event::Event;
use gtip::sim::workload::{FloodWorkload, WorkloadOptions};
use gtip::util::rng::Pcg32;

fn std_workload(graph: &gtip::graph::Graph, threads: usize, rng: &mut Pcg32) -> FloodWorkload {
    FloodWorkload::generate(
        graph,
        &WorkloadOptions { threads, horizon_ticks: 1500, hot_spot_period: 400, ..Default::default() },
        rng,
    )
}

/// Every injected thread is processed by its source at least once, and
/// the run drains (no lost/stuck events) across partitions.
#[test]
fn event_conservation_across_partitions() {
    let mut rng = Pcg32::new(1);
    let graph = preferential_attachment(120, 2, &mut rng);
    let machines = MachineConfig::homogeneous(4);
    for seed in 0..3u64 {
        let mut rng2 = Pcg32::new(seed);
        let workload = std_workload(&graph, 50, &mut rng2);
        let injected = workload.len() as u64;
        let assignment: Vec<usize> = (0..120).map(|_| rng2.index(4)).collect();
        let part = Partition::from_assignment(&graph, 4, assignment);
        let mut engine = SimEngine::new(
            &graph,
            machines.clone(),
            part,
            SimOptions::default(),
            workload.injections,
        );
        let stats = engine.run_to_completion();
        assert!(!stats.truncated, "seed {seed} truncated");
        assert!(stats.events_processed >= injected);
        assert!(engine.drained());
    }
}

/// A deliberately terrible partition (every neighbor pair split across
/// machines) must be slower and roll back more than a good one.
#[test]
fn bad_partition_hurts() {
    // Ring graph so "alternating" splits every edge.
    let n = 60;
    let mut b = GraphBuilder::with_nodes(n);
    for i in 0..n {
        b.add_edge(i, (i + 1) % n, 1.0);
    }
    let graph = b.build();
    let machines = MachineConfig::homogeneous(2);
    let make_workload = || {
        let mut rng = Pcg32::new(5);
        FloodWorkload::generate(
            &graph,
            &WorkloadOptions {
                threads: 40,
                horizon_ticks: 800,
                hot_spots: 0,
                hop_limit: 6,
                ..Default::default()
            },
            &mut rng,
        )
    };

    let run = |assignment: Vec<usize>| {
        let part = Partition::from_assignment(&graph, 2, assignment);
        let mut engine = SimEngine::new(
            &graph,
            machines.clone(),
            part,
            SimOptions { max_ticks: 500_000, ..Default::default() },
            make_workload().injections,
        );
        engine.run_to_completion()
    };

    // Good: two contiguous arcs (2 cut edges). Bad: alternating (n cut).
    let good = run((0..n).map(|i| if i < n / 2 { 0 } else { 1 }).collect());
    let bad = run((0..n).map(|i| i % 2).collect());
    assert!(!good.truncated && !bad.truncated);
    assert!(
        bad.ticks > good.ticks,
        "bad partition should be slower: {} vs {}",
        bad.ticks,
        good.ticks
    );
    assert!(
        bad.cross_machine_forwards > good.cross_machine_forwards,
        "bad partition should cross more"
    );
}

/// The full dynamic driver beats no-refinement on hot-spot workloads —
/// the paper's headline (Figs. 7/8) as an integration test.
#[test]
fn dynamic_refinement_beats_static() {
    let mut best_ratio = f64::INFINITY;
    for seed in [1u64, 2, 3] {
        let mut rng = Pcg32::new(seed);
        let graph = generate(GraphFamily::PreferentialAttachment, 150, &mut rng);
        let machines = MachineConfig::homogeneous(5);
        let arm = |refine_every: u64| {
            let mut rng2 = Pcg32::new(seed.wrapping_add(100));
            let workload = FloodWorkload::generate(
                &graph,
                &WorkloadOptions {
                    threads: 100,
                    horizon_ticks: 2500,
                    hot_spot_period: 400,
                    ..Default::default()
                },
                &mut rng2,
            );
            let options = DriverOptions {
                sim: SimOptions { max_ticks: 500_000, ..Default::default() },
                refine_every,
                framework: Framework::A,
                mu: 8.0,
                ticks_per_transfer: 0,
            };
            run_dynamic(&graph, &machines, workload, &options, &mut rng2)
        };
        let none = arm(0);
        let refined = arm(400);
        assert!(!none.stats.truncated && !refined.stats.truncated);
        best_ratio = best_ratio.min(refined.total_time() as f64 / none.total_time() as f64);
    }
    assert!(
        best_ratio < 0.95,
        "refinement never helped meaningfully (best ratio {best_ratio})"
    );
}

/// Failure injection: a workload whose every event lands on one LP (a
/// degenerate hot spot) must still drain, with refinement spreading the
/// neighborhood out.
#[test]
fn degenerate_single_hotspot_drains() {
    let mut rng = Pcg32::new(31);
    let graph = preferential_attachment(100, 2, &mut rng);
    let machines = MachineConfig::homogeneous(4);
    let injections: Vec<Injection> = (0..80)
        .map(|t| Injection {
            at_tick: (t * 7) as u64,
            lp: 0,
            event: Event::injection(t as u64 + 1, (t * 3) as u64, 3),
        })
        .collect();
    let part = Partition::from_assignment(&graph, 4, (0..100).map(|i| i % 4).collect());
    let mut engine = SimEngine::new(
        &graph,
        machines,
        part,
        SimOptions { max_ticks: 500_000, ..Default::default() },
        injections,
    );
    let stats = engine.run_to_completion();
    assert!(!stats.truncated);
    // Only the first injection is a fresh thread at LP0... all 80 are
    // distinct threads, each floods from LP0.
    assert!(stats.events_processed >= 80);
}

/// Failure injection: zero-delay everything (no inter-machine penalty)
/// must produce zero rollback-delay-induced stragglers on a single
/// machine.
#[test]
fn single_machine_no_cross_traffic() {
    let mut rng = Pcg32::new(37);
    let graph = preferential_attachment(80, 2, &mut rng);
    let machines = MachineConfig::homogeneous(1);
    let workload = std_workload(&graph, 40, &mut rng);
    let part = Partition::all_on_machine(&graph, 1, 0);
    let mut engine =
        SimEngine::new(&graph, machines, part, SimOptions::default(), workload.injections);
    let stats = engine.run_to_completion();
    assert_eq!(stats.cross_machine_forwards, 0);
    assert!(!stats.truncated);
}

/// GVT never regresses across an entire dynamic run with refinement.
#[test]
fn gvt_monotone_with_refinement() {
    let mut rng = Pcg32::new(41);
    let graph = preferential_attachment(100, 2, &mut rng);
    let machines = MachineConfig::homogeneous(4);
    let workload = std_workload(&graph, 60, &mut rng);
    let part = Partition::from_assignment(&graph, 4, (0..100).map(|i| i % 4).collect());
    let mut engine = SimEngine::new(
        &graph,
        machines.clone(),
        part,
        SimOptions::default(),
        workload.injections,
    );
    let mut last = 0;
    let mut ticks = 0u64;
    while engine.step() {
        assert!(engine.gvt() >= last, "GVT regressed at tick {ticks}");
        last = engine.gvt();
        ticks += 1;
        // Mid-run repartition every 300 ticks (the driver's behaviour).
        if ticks % 300 == 0 {
            let assignment: Vec<usize> =
                (0..100).map(|i| (i / 25) % 4).collect();
            engine.set_partition(Partition::from_assignment(&graph, 4, assignment));
        }
        if ticks > 400_000 {
            panic!("runaway");
        }
    }
}

/// Rollback accounting: cross-machine stragglers produce rollbacks and
/// anti-messages, and both counters move together.
#[test]
fn rollback_accounting_consistent() {
    let mut rng = Pcg32::new(43);
    let graph = preferential_attachment(120, 2, &mut rng);
    let machines = MachineConfig::homogeneous(4);
    let workload = std_workload(&graph, 80, &mut rng);
    let part = Partition::from_assignment(&graph, 4, (0..120).map(|_| rng.index(4)).collect());
    let mut engine = SimEngine::new(
        &graph,
        machines,
        part,
        SimOptions { inter_machine_delay: 6, ..Default::default() },
        workload.injections,
    );
    let stats = engine.run_to_completion();
    assert!(!stats.truncated);
    assert!(stats.rollbacks > 0, "expected rollbacks under high delay");
}

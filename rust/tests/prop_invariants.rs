//! Property-based invariant tests (DESIGN.md §5), via the testkit
//! runner: randomized graphs/partitions/weights, each property checked
//! across many generated cases with replayable seeds.

use gtip::game::cost::{CostModel, Framework};
use gtip::game::refine::{RefineEngine, RefineOptions};
use gtip::graph::generators::{erdos_renyi, preferential_attachment, table1_graph, WeightModel};
use gtip::graph::{metrics, Graph};
use gtip::partition::{global_cost, MachineConfig, Partition};
use gtip::sim::dynamic::{DynamicDriver, DynamicOptions, WeightEstimator};
use gtip::sim::engine::SimOptions;
use gtip::sim::fuzz::{shrink_steps, Mutator};
use gtip::sim::scenario::{DriftSchedule, ScenarioKind};
use gtip::util::bench::parse_json;
use gtip::util::rng::Pcg32;
use gtip::util::testkit::{assert_close, check_property, GenCtx, PropConfig, ScenarioFixture};

/// Random problem: graph + machines + partition + mu.
fn gen_problem(g: &mut GenCtx) -> (Graph, MachineConfig, Partition, f64) {
    let n = g.usize_in(8, 8 + 4 * g.size.max(4));
    let k = g.usize_in(2, 6);
    let family = g.usize_in(0, 2);
    let mut rng = g.rng.fork(0xF00D);
    let graph = match family {
        0 => table1_graph(n, 2, 5.min(n - 1), WeightModel::default(), &mut rng),
        1 => preferential_attachment(n.max(5), 2, &mut rng),
        _ => erdos_renyi(n, (4.0 / n as f64).min(0.9), &mut rng),
    };
    let n = graph.node_count();
    let speeds: Vec<f64> = (0..k).map(|_| g.f64_in(0.05, 1.0)).collect();
    let machines = MachineConfig::from_speeds(&speeds);
    let assignment: Vec<usize> = (0..n).map(|_| g.usize_in(0, k - 1)).collect();
    let part = Partition::from_assignment(&graph, k, assignment);
    let mu = g.f64_in(0.0, 16.0);
    (graph, machines, part, mu)
}

/// Thm 3.1: for ANY single-node move, dC0 == 2*dC_l exactly.
#[test]
fn prop_potential_identity_a() {
    check_property("potential_identity_a", PropConfig::default(), |g| {
        let (graph, machines, part, mu) = gen_problem(g);
        let model = CostModel::new(&graph, machines.clone(), mu, Framework::A);
        let node = g.usize_in(0, graph.node_count() - 1);
        let to = g.usize_in(0, machines.count() - 1);
        let before = global_cost::c0(&graph, &machines, &part, mu);
        let predicted = model.potential_delta(&part, node, to);
        let mut p2 = part.clone();
        p2.transfer(&graph, node, to);
        let after = global_cost::c0(&graph, &machines, &p2, mu);
        assert_close(after - before, predicted, 1e-7, "dC0 == 2*dC_l")
    });
}

/// Thm 5.1: for ANY single-node move, dC~0 == C~_l(new) - C~_l(old).
#[test]
fn prop_potential_identity_b() {
    check_property("potential_identity_b", PropConfig::default(), |g| {
        let (graph, machines, part, mu) = gen_problem(g);
        let model = CostModel::new(&graph, machines.clone(), mu, Framework::B);
        let node = g.usize_in(0, graph.node_count() - 1);
        let to = g.usize_in(0, machines.count() - 1);
        let before = global_cost::c0_tilde(&graph, &machines, &part, mu);
        let predicted = model.potential_delta(&part, node, to);
        let mut p2 = part.clone();
        p2.transfer(&graph, node, to);
        let after = global_cost::c0_tilde(&graph, &machines, &p2, mu);
        assert_close(after - before, predicted, 1e-7, "dC~0 == dC~_l")
    });
}

/// C0 is the sum of node costs (social welfare decomposition).
#[test]
fn prop_c0_is_sum_of_node_costs() {
    check_property("c0_sum_decomposition", PropConfig::default(), |g| {
        let (graph, machines, part, mu) = gen_problem(g);
        let model = CostModel::new(&graph, machines.clone(), mu, Framework::A);
        let sum: f64 = (0..graph.node_count()).map(|i| model.current_cost(&part, i)).sum();
        let c0 = global_cost::c0(&graph, &machines, &part, mu);
        assert_close(sum, c0, 1e-7, "sum C_i == C0")
    });
}

/// Refinement: strict potential descent per transfer, convergence to a
/// Nash equilibrium, incremental state consistency.
#[test]
fn prop_refinement_descends_and_converges() {
    let config = PropConfig { cases: 48, ..Default::default() };
    check_property("refine_descends_converges", config, |g| {
        let (graph, machines, part, mu) = gen_problem(g);
        let fw = if g.usize_in(0, 1) == 0 { Framework::A } else { Framework::B };
        let mut engine = RefineEngine::new(&graph, &machines, part, mu, fw);
        let report = engine.run(&RefineOptions { track_potential: true, ..Default::default() });
        if !report.converged {
            return Err("did not converge".into());
        }
        for w in report.potential_trace.windows(2) {
            if w[1] >= w[0] + 1e-9 * (1.0 + w[0].abs()) {
                return Err(format!("non-descent step {} -> {}", w[0], w[1]));
            }
        }
        engine.validate().map_err(|e| format!("state drift: {e}"))?;
        // Nash: no node can improve unilaterally.
        let model = engine.model();
        for i in 0..graph.node_count() {
            let (j, _) = model.dissatisfaction(engine.partition(), i);
            if j > 1e-6 {
                return Err(format!("node {i} still dissatisfied: {j}"));
            }
        }
        Ok(())
    });
}

/// Augmented (migration-cost-aware) game, DESIGN.md §9: with a random
/// positive per-move charge `c`, the augmented potential
/// `Φ' = Φ + c·(#transfers)` strictly decreases on EVERY accepted
/// transfer, under both frameworks — i.e. the raw potential drops by
/// strictly more than the charge per move (for A by more than 2c).
/// Also: the run still converges, and convergence is an augmented Nash
/// equilibrium (no node's raw gain beats the charge).
#[test]
fn prop_augmented_potential_strictly_descends() {
    let config = PropConfig { cases: 48, ..Default::default() };
    check_property("augmented_potential_descent", config, |g| {
        let (graph, machines, part, mu) = gen_problem(g);
        let fw = if g.usize_in(0, 1) == 0 { Framework::A } else { Framework::B };
        let charge = g.f64_in(0.01, 20.0);
        let mut engine = RefineEngine::new(&graph, &machines, part, mu, fw)
            .with_migration_charge(charge);
        let report = engine.run(&RefineOptions { track_potential: true, ..Default::default() });
        if !report.converged {
            return Err("augmented game did not converge".into());
        }
        // Each trace step is the raw potential after one transfer, so
        // the augmented descent Φ'_{t+1} < Φ'_t is: raw drop > charge.
        for w in report.potential_trace.windows(2) {
            let aug_step = (w[1] + charge) - w[0];
            if aug_step >= 1e-9 * (1.0 + w[0].abs()) {
                return Err(format!(
                    "augmented potential rose: {} + {charge} >= {} (fw {fw})",
                    w[1], w[0]
                ));
            }
        }
        // End-to-end: Φ_after + c·T <= Φ_before (with T transfers).
        let start = report
            .potential_trace
            .first()
            .copied()
            .unwrap_or(engine.potential());
        let aug_end = global_cost::augmented(engine.potential(), charge, report.transfers);
        if report.transfers > 0 && aug_end >= start + 1e-9 * (1.0 + start.abs()) {
            return Err(format!("augmented total rose: {aug_end} vs {start}"));
        }
        // The churn bound (a theorem, unlike trajectory monotonicity):
        // each transfer drops the raw potential by at least the charge
        // (2x for A), so T <= (Φ_start - Φ_end) / min_drop.
        let min_drop = match fw {
            Framework::A => 2.0 * charge,
            Framework::B => charge,
        };
        let bound = (start - engine.potential()) / min_drop;
        if report.transfers as f64 > bound * (1.0 + 1e-9) + 1e-9 {
            return Err(format!(
                "churn bound violated ({fw}): {} transfers > (Φ {start} - {}) / {min_drop}",
                report.transfers,
                engine.potential()
            ));
        }
        // Augmented Nash: nobody's raw gain beats the charge any more.
        for i in 0..graph.node_count() {
            let (j, _) = engine.model().dissatisfaction(engine.partition(), i);
            if j > 1e-6 {
                return Err(format!("node {i} still augmented-dissatisfied: {j}"));
            }
        }
        engine.validate().map_err(|e| format!("state drift under charge: {e}"))
    });
}

/// Churn damping on a FIXED fixture (deterministic, not randomized —
/// trajectory monotonicity in the charge is an empirical property of a
/// concrete fixture, not a theorem, so it is pinned on one seed per
/// framework rather than asserted across random cases): total
/// transfers are monotone non-increasing along a steeply growing
/// migration-charge ladder, and a prohibitive charge provably freezes
/// the partition (no raw gain on these small fixtures can approach
/// 1e9). The randomized, theorem-backed counterpart — the churn bound
/// `T ≤ ΔΦ / c_mig` — lives in `prop_augmented_potential_strictly_descends`.
#[test]
fn churn_monotone_in_migration_charge_on_fixed_fixture() {
    for (fw, seed) in [(Framework::A, 71u64), (Framework::B, 72u64)] {
        let mut rng = Pcg32::new(seed);
        let graph = preferential_attachment(90, 2, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let assignment: Vec<usize> = (0..graph.node_count()).map(|_| rng.index(4)).collect();
        let part = Partition::from_assignment(&graph, 4, assignment);
        let mut last = usize::MAX;
        for &charge in &[0.0, 8.0, 64.0, 512.0, 1e9] {
            let mut engine = RefineEngine::new(&graph, &machines, part.clone(), 8.0, fw)
                .with_migration_charge(charge);
            let report = engine.run(&RefineOptions::default());
            assert!(report.converged, "{fw}: no convergence at charge {charge}");
            // Rung-to-rung monotonicity is empirical (a higher charge
            // reroutes early moves and can legally enable a few more
            // later ones), so a small slack guards against seed luck
            // while a gross inversion — churn NOT being damped — still
            // fails loudly.
            let slack = last / 8 + 1;
            assert!(
                report.transfers <= last.saturating_add(slack),
                "churn rose with the charge ({fw}): {last} -> {} at charge {charge}",
                report.transfers
            );
            last = last.min(report.transfers);
        }
        assert_eq!(last, 0, "{fw}: a 1e9 charge should freeze everything");
    }
}

/// Dissatisfaction is non-negative and zero exactly at best response.
#[test]
fn prop_dissatisfaction_nonnegative() {
    check_property("dissatisfaction_nonneg", PropConfig::default(), |g| {
        let (graph, machines, part, mu) = gen_problem(g);
        for fw in [Framework::A, Framework::B] {
            let model = CostModel::new(&graph, machines.clone(), mu, fw);
            for i in 0..graph.node_count() {
                let (j, best) = model.dissatisfaction(&part, i);
                if j < 0.0 {
                    return Err(format!("negative dissatisfaction {j} at node {i}"));
                }
                let cur = model.current_cost(&part, i);
                let best_cost = model.node_cost(&part, i, best);
                assert_close(j, (cur - best_cost).max(0.0), 1e-8, "J == cur - min")?;
            }
        }
        Ok(())
    });
}

/// Partition transfer bookkeeping: loads/counts always equal a fresh
/// scan, node conservation holds.
#[test]
fn prop_partition_bookkeeping() {
    check_property("partition_bookkeeping", PropConfig::default(), |g| {
        let (graph, machines, mut part, _) = gen_problem(g);
        let k = machines.count();
        let moves = g.usize_in(1, 50);
        for _ in 0..moves {
            let node = g.usize_in(0, graph.node_count() - 1);
            let to = g.usize_in(0, k - 1);
            part.transfer(&graph, node, to);
        }
        part.validate(&graph)?;
        let total: usize = part.counts().iter().sum();
        if total != graph.node_count() {
            return Err(format!("node leak: {total} vs {}", graph.node_count()));
        }
        Ok(())
    });
}

/// Cut weight: symmetric under machine relabeling, zero for the
/// everything-on-one-machine assignment.
#[test]
fn prop_cut_weight_invariants() {
    check_property("cut_weight_invariants", PropConfig::default(), |g| {
        let (graph, machines, part, _) = gen_problem(g);
        let k = machines.count();
        let assign = part.assignment().to_vec();
        let cut = metrics::cut_weight(&graph, &assign);
        if cut < 0.0 {
            return Err("negative cut".into());
        }
        // Relabel machines with a rotation: cut unchanged.
        let rotated: Vec<usize> = assign.iter().map(|&m| (m + 1) % k).collect();
        assert_close(cut, metrics::cut_weight(&graph, &rotated), 1e-12, "relabel-invariant")?;
        let lumped = vec![0usize; graph.node_count()];
        if metrics::cut_weight(&graph, &lumped) != 0.0 {
            return Err("lumped cut not zero".into());
        }
        Ok(())
    });
}

/// Graph serialization round-trips exactly.
#[test]
fn prop_graph_io_round_trip() {
    let config = PropConfig { cases: 32, ..Default::default() };
    check_property("graph_io_round_trip", config, |g| {
        let (graph, _, _, _) = gen_problem(g);
        let mut buf = Vec::new();
        gtip::graph::io::write_graph(&graph, &mut buf).map_err(|e| e.to_string())?;
        let g2 = gtip::graph::io::read_graph(std::io::Cursor::new(buf)).map_err(|e| e.to_string())?;
        if g2.node_count() != graph.node_count() || g2.edge_count() != graph.edge_count() {
            return Err("shape mismatch after round trip".into());
        }
        for u in 0..graph.node_count() {
            if g2.neighbors(u) != graph.neighbors(u) {
                return Err(format!("adjacency mismatch at node {u}"));
            }
            assert_close(g2.node_weight(u), graph.node_weight(u), 1e-12, "node weight")?;
        }
        Ok(())
    });
}

/// Dense cost matrices agree with scalar evaluation everywhere.
#[test]
fn prop_dense_matches_scalar() {
    let config = PropConfig { cases: 48, ..Default::default() };
    check_property("dense_matches_scalar", config, |g| {
        let (graph, machines, part, mu) = gen_problem(g);
        let dense = gtip::game::cost::dense_cost_matrices(&graph, &machines, &part, mu);
        let ma = CostModel::new(&graph, machines.clone(), mu, Framework::A);
        let mb = CostModel::new(&graph, machines.clone(), mu, Framework::B);
        for i in 0..dense.n {
            for m in 0..dense.k {
                assert_close(
                    dense.costs_a[i * dense.k + m],
                    ma.node_cost(&part, i, m),
                    1e-8,
                    "dense A",
                )?;
                assert_close(
                    dense.costs_b[i * dense.k + m],
                    mb.node_cost(&part, i, m),
                    1e-8,
                    "dense B",
                )?;
            }
        }
        Ok(())
    });
}

/// Closed-loop epoch invariants (`sim::dynamic`): node count is
/// conserved across every epoch's migration wave, and each refinement
/// epoch descends its measured potential (Thm 4.1, re-applied from the
/// warm start every epoch).
#[test]
fn prop_dynamic_epochs_conserve_nodes_and_descend() {
    let config = PropConfig { cases: 10, ..Default::default() };
    check_property("dynamic_epoch_invariants", config, |g| {
        let kind = ScenarioKind::ALL[g.usize_in(0, 3)];
        let seed = g.rng.next_u64();
        let fixture = ScenarioFixture::new(kind, seed)
            .nodes(g.usize_in(40, 80))
            .machines(g.usize_in(2, 4))
            .threads(g.usize_in(24, 48))
            .horizon(g.usize_in(400, 800) as u64)
            .build();
        let n = fixture.graph.node_count();
        let options = DynamicOptions {
            sim: SimOptions { max_ticks: 400_000, ..Default::default() },
            epoch_ticks: g.usize_in(60, 200) as u64,
            ..Default::default()
        };
        let mut driver = DynamicDriver::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            fixture.scenario.injections.clone(),
            WeightEstimator::ewma(0.5),
            options,
        );
        while driver.run_epoch() {
            let part = driver.engine().partition();
            let total: usize = part.counts().iter().sum();
            if total != n {
                return Err(format!("node leak after migration: {total} vs {n}"));
            }
            if part.assignment().iter().any(|&m| m >= part.machine_count()) {
                return Err("node on invalid machine".into());
            }
        }
        for e in driver.epochs() {
            if let Some(r) = &e.refine {
                if r.potential_after > r.potential_before + 1e-9 * (1.0 + r.potential_before.abs())
                {
                    return Err(format!(
                        "epoch {}: potential rose {} -> {}",
                        e.epoch, r.potential_before, r.potential_after
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Adversarial dynamic re-weighting (zeros, duplicated constants, huge
/// spread, on nodes *and* edges): the refinement engine's incremental
/// state must survive arbitrary transfers plus a `resync_weights`
/// rebuild — `validate()` passes, the potential is unchanged by the
/// resync, and refinement still converges with strict descent.
#[test]
fn prop_resync_validate_under_adversarial_weights() {
    let config = PropConfig { cases: 32, ..Default::default() };
    check_property("resync_adversarial_weights", config, |g| {
        let hint = g.usize_in(8, 8 + 3 * g.size.max(4));
        let mut rng = g.rng.fork(0xBEEF);
        let mut graph = preferential_attachment(hint.max(5), 2, &mut rng);
        let n = graph.node_count();
        // Zeros, a duplicated constant, and a 5-orders-of-magnitude
        // spread (bounded so potential deltas stay well above f64 ulp —
        // convergence is a property of exact arithmetic).
        let node_w: Vec<f64> = (0..n)
            .map(|_| match g.usize_in(0, 2) {
                0 => 0.0,
                1 => 7.0,
                _ => g.f64_in(0.01, 1e3),
            })
            .collect();
        graph.set_node_weights(&node_w);
        let edges: Vec<(usize, usize)> = graph.edges().map(|(u, v, _)| (u, v)).collect();
        for (u, v) in edges {
            let c = match g.usize_in(0, 2) {
                0 => 0.0,
                1 => 3.0,
                _ => g.f64_in(0.0, 1e3),
            };
            graph.set_edge_weight(u, v, c);
        }
        let k = g.usize_in(2, 5);
        let machines = MachineConfig::homogeneous(k);
        let assignment: Vec<usize> = (0..n).map(|_| g.usize_in(0, k - 1)).collect();
        let part = Partition::from_assignment(&graph, k, assignment);
        let mu = g.f64_in(0.0, 16.0);
        let fw = if g.usize_in(0, 1) == 0 { Framework::A } else { Framework::B };
        let mut engine = RefineEngine::new(&graph, &machines, part, mu, fw);

        // Arbitrary (non-best-response) transfers, then a from-scratch
        // resync: all incremental state must agree with a rebuild.
        for _ in 0..g.usize_in(1, 20) {
            let node = g.usize_in(0, n - 1);
            let to = g.usize_in(0, k - 1);
            if engine.partition().machine_of(node) != to {
                engine.apply_transfer(node, to);
            }
        }
        let before = engine.potential();
        engine.resync_weights();
        engine.validate().map_err(|e| format!("validate after resync: {e}"))?;
        assert_close(engine.potential(), before, 1e-6, "resync changed the potential")?;

        // epsilon well above f64 evaluation noise at this weight scale.
        let report = engine.run(&RefineOptions {
            track_potential: true,
            epsilon: 1e-6,
            ..Default::default()
        });
        if !report.converged {
            return Err("refinement did not converge on adversarial weights".into());
        }
        for w in report.potential_trace.windows(2) {
            if w[1] >= w[0] + 1e-9 * (1.0 + w[0].abs()) {
                return Err(format!("non-descent step {} -> {}", w[0], w[1]));
            }
        }
        engine.validate().map_err(|e| format!("validate after run: {e}"))?;
        Ok(())
    });
}

/// Random mutator over a random node count, for the genome properties.
fn gen_mutator(g: &mut GenCtx) -> Mutator {
    Mutator {
        nodes: g.usize_in(8, 8 + 4 * g.size.max(4)),
        thread_budget: g.usize_in(4, 96) as u32,
        epoch_pm: g.usize_in(1, 1000) as u32,
        max_genes: g.usize_in(4, 16),
    }
}

/// Genome operators preserve schedule validity: random generation,
/// mutation, crossover, and every delta-debug shrink candidate keep
/// monotone event times, in-range LP ids, and bounded fields.
#[test]
fn prop_genome_ops_preserve_validity() {
    let config = PropConfig { cases: 64, ..Default::default() };
    check_property("genome_ops_validity", config, |g| {
        let mutator = gen_mutator(g);
        let horizon = g.usize_in(100, 3_000) as u64;
        let mut rng = g.rng.fork(0xFA22);
        let a = mutator.random_schedule(horizon, 4, &mut rng);
        a.validate(mutator.nodes).map_err(|e| format!("random: {e}"))?;
        let mut m = a.clone();
        for round in 0..g.usize_in(1, 6) {
            m = mutator.mutate(&m, &mut rng);
            m.validate(mutator.nodes)
                .map_err(|e| format!("mutate round {round}: {e}"))?;
        }
        let b = mutator.random_schedule(horizon, 4, &mut rng);
        let x = mutator.crossover(&m, &b, &mut rng);
        x.validate(mutator.nodes).map_err(|e| format!("crossover: {e}"))?;
        for (i, candidate) in shrink_steps(&x).into_iter().enumerate() {
            candidate
                .validate(mutator.nodes)
                .map_err(|e| format!("shrink candidate {i}: {e}"))?;
            // Shrink candidates must actually shrink.
            let smaller = candidate.genes.len() < x.genes.len()
                || candidate.total_threads() < x.total_threads()
                || candidate.genes.iter().map(|g| g.len_pm as u64).sum::<u64>()
                    < x.genes.iter().map(|g| g.len_pm as u64).sum::<u64>()
                || candidate.genes.iter().map(|g| g.radius as u64).sum::<u64>()
                    < x.genes.iter().map(|g| g.radius as u64).sum::<u64>();
            if !smaller {
                return Err(format!("shrink candidate {i} did not reduce the genome"));
            }
        }
        Ok(())
    });
}

/// The genome serializes to JSON and back **exactly** (all-integer
/// representation: no float round-trip risk), and the round-tripped
/// genome compiles to the identical injection schedule.
#[test]
fn prop_genome_serialization_round_trips() {
    let config = PropConfig { cases: 64, ..Default::default() };
    check_property("genome_json_round_trip", config, |g| {
        let mutator = gen_mutator(g);
        let horizon = g.usize_in(100, 3_000) as u64;
        let mut rng = g.rng.fork(0x5E41);
        let mut schedule = mutator.random_schedule(horizon, 4, &mut rng);
        for _ in 0..g.usize_in(0, 4) {
            schedule = mutator.mutate(&schedule, &mut rng);
        }
        let text = schedule.to_json().render();
        let parsed = parse_json(&text).map_err(|e| format!("parse: {e} in {text}"))?;
        let back = DriftSchedule::from_json(&parsed).map_err(|e| format!("decode: {e}"))?;
        if back != schedule {
            return Err(format!("round trip drifted:\n  {schedule:?}\n  {back:?}"));
        }
        Ok(())
    });
}

/// PRNG distribution sanity under arbitrary seeds (not just the fixed
/// unit-test seeds).
#[test]
fn prop_rng_uniformity() {
    let config = PropConfig { cases: 16, ..Default::default() };
    check_property("rng_uniformity", config, |g| {
        let seed = g.rng.next_u64();
        let mut rng = Pcg32::new(seed);
        let buckets = 8usize;
        let mut counts = vec![0u32; buckets];
        let trials = 8000;
        for _ in 0..trials {
            counts[rng.gen_below(buckets as u32) as usize] += 1;
        }
        let expect = trials as f64 / buckets as f64;
        for (i, &c) in counts.iter().enumerate() {
            if (c as f64 - expect).abs() > 5.0 * expect.sqrt() {
                return Err(format!("bucket {i} count {c} vs expected {expect} (seed {seed:#x})"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Coordinator wire codec (coordinator::net)
// ---------------------------------------------------------------------

use gtip::coordinator::net::{decode_payload, encode_frame, Frame};
use gtip::coordinator::protocol::Message;
use gtip::partition::MachineId;

/// Random protocol message across all four variants, with adversarial
/// field magnitudes (huge seqs/node ids, empty through size-hinted
/// loads vectors, extreme f64s).
fn gen_message(g: &mut GenCtx) -> Message {
    let extreme = [0.0f64, -0.0, 1.5, -3.25, f64::MAX, f64::MIN_POSITIVE, 1e300, -1e-300];
    match g.usize_in(0, 3) {
        0 => Message::TakeMyTurn {
            consecutive_forfeits: g.usize_in(0, 1 << 20),
            transfers_so_far: g.usize_in(0, 1 << 30),
        },
        1 => Message::ReceiveNode {
            seq: g.rng.next_u64(),
            node: g.usize_in(0, 1 << 30),
            from: g.usize_in(0, 64) as MachineId,
            to: g.usize_in(0, 64) as MachineId,
        },
        2 => {
            let loads = g.vec_of(0, 64, |g| {
                let i = g.usize_in(0, 7);
                extreme[i] * if g.usize_in(0, 1) == 0 { 1.0 } else { -1.0 }
            });
            Message::RegularUpdate {
                seq: g.rng.next_u64(),
                node: g.usize_in(0, 1 << 30),
                from: g.usize_in(0, 64) as MachineId,
                to: g.usize_in(0, 64) as MachineId,
                loads,
            }
        }
        _ => Message::Shutdown {
            total_transfers: g.rng.next_u64(),
            converged: g.usize_in(0, 1) == 1,
        },
    }
}

/// Every message round-trips through the wire codec exactly, and the
/// encoded frame length equals `Message::wire_bytes` — the number both
/// transports feed into `OverheadStats`.
#[test]
fn prop_wire_codec_round_trips_with_exact_sizes() {
    check_property("wire_codec_round_trip", PropConfig::default(), |g| {
        let msg = gen_message(g);
        let bytes = encode_frame(&Frame::Msg(msg.clone()));
        if bytes.len() != msg.wire_bytes() {
            return Err(format!(
                "{}: encoded {} bytes but wire_bytes says {}",
                msg.tag(),
                bytes.len(),
                msg.wire_bytes()
            ));
        }
        let decoded = decode_payload(&bytes[4..]).map_err(|e| format!("decode: {e}"))?;
        if decoded != Frame::Msg(msg.clone()) {
            return Err(format!("round trip drifted: {msg:?} -> {decoded:?}"));
        }
        Ok(())
    });
}

/// Mangled frames — truncated at any point, unknown tags, trailing
/// garbage — must return clean errors, never panic.
#[test]
fn prop_wire_codec_rejects_mangled_frames() {
    check_property("wire_codec_mangling", PropConfig::default(), |g| {
        let msg = gen_message(g);
        let bytes = encode_frame(&Frame::Msg(msg.clone()));
        let payload = &bytes[4..];

        // Truncation at a random cut is an error (empty prefix included).
        let cut = g.usize_in(0, payload.len() - 1);
        if decode_payload(&payload[..cut]).is_ok() {
            return Err(format!("{}: truncated to {cut} bytes still decoded", msg.tag()));
        }

        // Trailing garbage is an error.
        let mut padded = payload.to_vec();
        padded.push(g.usize_in(0, 255) as u8);
        if decode_payload(&padded).is_ok() {
            return Err(format!("{}: trailing byte accepted", msg.tag()));
        }

        // An unknown tag is an error (tags 5..=15 and 21.. are unused).
        let mut retagged = payload.to_vec();
        retagged[0] = 5 + g.usize_in(0, 10) as u8;
        if decode_payload(&retagged).is_ok() {
            return Err("unknown tag accepted".into());
        }
        Ok(())
    });
}

#!/usr/bin/env bash
# Module-size gate (DESIGN.md §13): no file under rust/src/ may grow
# past LIMIT lines. The PR-10 decomposition split every oversized
# module (coordinator/net, sim/dynamic, sim/engine, sim/fuzz,
# experiments/cmd); this gate keeps the next net.rs from re-accreting.
#
# Allowlisted: sim/legacy.rs — the retained pre-SoA engine, frozen as
# a differential-testing oracle, is exempt by design.
#
# Usage: scripts/ci/file_size_gate.sh [ROOT]   (ROOT defaults to rust/src)
set -euo pipefail

LIMIT=1200
ROOT="${1:-rust/src}"
ALLOWLIST=(
  "rust/src/sim/legacy.rs"
)

fail=0
while IFS= read -r file; do
  for allowed in "${ALLOWLIST[@]}"; do
    if [ "$file" = "$allowed" ]; then
      continue 2
    fi
  done
  lines=$(wc -l < "$file")
  if [ "$lines" -gt "$LIMIT" ]; then
    echo "::error file=$file::$file is $lines lines (limit $LIMIT); split it (see DESIGN.md §13)"
    fail=1
  fi
done < <(find "$ROOT" -name '*.rs' | sort)

if [ "$fail" -ne 0 ]; then
  echo "file size gate FAILED: split the files above into focused submodules"
  exit 1
fi
echo "file size gate OK: every $ROOT file is <= $LIMIT lines"

# Shared helper for the net-smoke loopback clusters: derive a per-run
# TCP port base instead of hard-coding one. Keyed on GITHUB_RUN_ID so
# a re-run (or a cancelled run whose workers are still dying) on a
# shared runner does not collide with its predecessor's listeners;
# falls back to the shell PID for local invocations.
#
# Usage:  . scripts/ci/ports.sh
#         port=$(net_smoke_port_base 0)   # slot 0, 1, 2, ... per case
#
# Each slot owns a disjoint 32-port window (the largest case is a
# 9-process cluster run at two graph sizes on fresh ports), and every
# base stays inside [20000, 60000) — clear of the ephemeral range's
# top end and of well-known ports.
net_smoke_port_base() {
  local slot="${1:?usage: net_smoke_port_base SLOT}"
  local seed="${GITHUB_RUN_ID:-$$}"
  echo $(( 20000 + (seed % 1000) * 32 + slot * 32 ))
}
